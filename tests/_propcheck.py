"""Seeded fallback property-testing shim for offline containers.

This container has no network pip index and no ``hypothesis`` wheel baked
in, so the tier-1 suite cannot import it.  Test modules fall back to this
shim::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

Semantics (deliberately tiny, covering only what the suite uses):

 - ``strategies.integers/floats/sampled_from/booleans`` draw from a
   ``numpy.random.Generator`` seeded deterministically from the test's
   qualified name, so runs are reproducible without example databases.
 - ``@given(*strategies)`` maps strategies onto the *last* len(strategies)
   parameters (hypothesis fills rightmost-first), runs ``max_examples``
   drawn examples sequentially, and re-raises the first failure with the
   failing example attached to the assertion message.
 - ``@settings(max_examples=..., deadline=...)`` only honours
   ``max_examples``; deadlines are meaningless for a sequential loop.

No shrinking, no example database — failures print the drawn arguments so
they can be replayed by hand.
"""
from __future__ import annotations

import inspect
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw, label):
        self._draw = draw
        self._label = label

    def __repr__(self):
        return f"_propcheck.{self._label}"


def _integers(min_value, max_value):
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})",
    )


def _floats(min_value, max_value):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})",
    )


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(
        lambda rng: elements[int(rng.integers(len(elements)))],
        f"sampled_from({elements!r})",
    )


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)), "booleans()")


strategies = types.SimpleNamespace(
    integers=_integers,
    floats=_floats,
    sampled_from=_sampled_from,
    booleans=_booleans,
)


class settings:
    """Decorator mirroring hypothesis.settings; keeps only max_examples."""

    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._propcheck_settings = self
        return fn


def given(*strats):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if len(strats) > len(params):
            raise TypeError(
                f"@given got {len(strats)} strategies for {len(params)} "
                f"parameters of {fn.__name__}"
            )
        passthrough = params[: len(params) - len(strats)]

        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_propcheck_settings", None)
            n = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            rng = np.random.default_rng(
                zlib.adler32(fn.__qualname__.encode("utf-8"))
            )
            for example in range(n):
                drawn = [s._draw(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {example} with "
                        f"drawn arguments {tuple(drawn)!r}: {exc!r}"
                    ) from exc

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        # Carry @settings applied *below* @given, and hide the drawn
        # parameters from pytest's fixture resolution.
        wrapper.__dict__.update(fn.__dict__)
        wrapper.__signature__ = sig.replace(parameters=passthrough)
        return wrapper

    return deco
