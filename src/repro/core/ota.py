"""Over-the-air (OTA) analog aggregation physics (Bereyhi et al. 2206.06679).

A fundamentally different uplink from the paper's digital NOMA/TDMA: every
scheduled device transmits its *raw* model update simultaneously over the
shared slot, scaled so the channel itself computes the FedAvg sum.  The PS
receives the noisy analog superposition

    y = sum_{k in A} h_k b_k delta_k + n,        n ~ N(0, sigma_ota^2 I)

and never decodes a per-device payload — DoReFa quantization and top-k
sparsification are structurally bypassed (``FLConfig`` rejects the combos).

Truncated channel inversion sets the transmit amplitudes: device k sends
``b_k = sqrt(eta) * w_k / h_k`` (w_k its FedAvg weight), so each participant
contributes exactly ``sqrt(eta) * w_k * delta_k`` after the channel.  The
participation set A drops devices whose channel is too weak to invert —
``h_k >= threshold * max_{j} h_j`` — and the power scalar eta is pinned by
the §IV per-device budget: the transmit power of device k is
``eta * w_k^2 * ||delta_k||^2 / h_k^2 <= pmax``, so

    eta = min_{k in A} pmax * h_k^2 / (w_k^2 * ||delta_k||^2)

(the binding device transmits at exactly pmax).  The PS estimate is

    theta_update = ( sum_{k in A} w_k delta_k  +  n / sqrt(eta) ) / sum_{k in A} w_k

— at ``noise_std = 0`` and ``threshold = 0`` this is exactly the weighted
FedAvg aggregate; growing noise or truncation trades accuracy for power.

Everything here is traced JAX math shared verbatim by the batched per-round
engine, the scanned horizon and the legacy oracle driver
(:func:`superpose_tree` is the single aggregation operator all three call),
with the receiver noise drawn from a dedicated seeded stream
(:func:`horizon_keys`) so per-round and scanned drivers consume identical
draws.  Airtime: OTA rounds charge one shared uplink slot, exactly like
NOMA's (``fl._round_physics``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import errors

UPLINK_MODES = ("noma", "tdma", "ota")
# fl.run_federated_learning uplink modes; FLConfig validates ``uplink``
# against this tuple ("noma"/"tdma" are the paper's digital §IV uplinks,
# "ota" the analog superposition subsystem of this module).

OTA_SEED_OFFSET = 29
# decorrelates the receiver-noise stream from the model-init / channel
# streams (FLConfig.seed), the scheduling permutation (+17,
# scheduling.RandomPolicy.SEED_OFFSET) and the eval sampler (+23,
# client_bank.EVAL_SEED_OFFSET)

_TINY = 1e-30   # divide guard; far below any realized f32 weight sum


def check_uplink(uplink: str, *, compression: str, topk: float,
                 power_mode: str) -> None:
    """The uplink-combination rules, shared by ``FLConfig.__post_init__``
    and the fl.py drivers (the uplink can also arrive as a call-site
    argument overriding ``cfg.uplink``).  Raises ValueError with pinned
    messages on incoherent combos."""
    if uplink not in UPLINK_MODES:
        raise ValueError(
            errors.ERR_UNKNOWN_UPLINK.format(uplink=uplink, modes=UPLINK_MODES)
        )
    if uplink == "ota":
        if topk < 1.0:
            raise ValueError(errors.ERR_OTA_TOPK)
        if compression != "none":
            raise ValueError(errors.ERR_OTA_COMPRESSION)
        if power_mode == "mapel":
            raise ValueError(errors.ERR_OTA_MAPEL)
    elif power_mode == "ota-align":
        raise ValueError(errors.ERR_OTA_ALIGN_UPLINK)


def horizon_keys(seed: int, num_rounds: int) -> np.ndarray:
    """(T, 2) uint32 per-round receiver-noise keys.

    ``fold_in(PRNGKey(seed + OTA_SEED_OFFSET), t)`` on the host — threefry
    is deterministic, so the per-round driver (indexing row t) and the
    scanned horizon (consuming the stack as scan inputs) draw bit-identical
    noise.
    """
    base = jax.random.PRNGKey(int(seed) + OTA_SEED_OFFSET)
    return np.stack(
        [np.asarray(jax.random.fold_in(base, t)) for t in range(num_rounds)]
    )


def superpose_flat(
    flat: jax.Array,        # (K, P) raw client update rows
    gains_k: jax.Array,     # (K,) channel amplitudes h_k at this round
    agg_w: jax.Array,       # (K,) FedAvg weights (0 marks padding rows)
    key: jax.Array,         # (2,) uint32 receiver-noise key
    *,
    pmax: float,
    noise_std: float,
    threshold: float,
    use_pallas: bool = False,
) -> jax.Array:
    """The OTA receiver estimate for one round; returns the (P,) update.

    Implements the module-docstring signal model end to end: participation
    mask (traced — zero-weight padding rows and sub-threshold channels drop
    out), power-budget eta, analog superposition, receiver noise scaled by
    1/sqrt(eta), and the 1/sum(w_A) renormalization.  Rounds with no
    participants (all-padding scan rows) return exactly zero.  The weighted
    reduction runs through the XLA einsum or, under ``use_pallas``, the
    fused scale+superpose+denoise Pallas kernel
    (:func:`repro.kernels.aggregate.ota_aggregate_pallas`).
    """
    from repro.kernels.aggregate import ota_aggregate_pallas

    k, p = flat.shape
    flat = flat.astype(jnp.float32)
    h = gains_k.astype(jnp.float32)
    w = agg_w.astype(jnp.float32)

    cand = w > 0.0
    hmax = jnp.max(jnp.where(cand, h, 0.0), initial=0.0)
    mask = cand & (h > 0.0) & (h >= jnp.float32(threshold) * hmax)

    energy = jnp.sum(flat * flat, axis=1)               # (K,) ||delta_k||^2
    # per-participant eta cap: pmax h_k^2 / (w_k^2 ||delta_k||^2); a
    # zero-energy delta imposes no cap (its transmit power is zero anyway)
    den = w * w * energy
    cap = jnp.where(
        mask & (den > 0.0),
        jnp.float32(pmax) * h * h / jnp.maximum(den, _TINY),
        jnp.inf,
    )
    eta = jnp.min(cap, initial=jnp.inf)

    wsum = jnp.sum(jnp.where(mask, w, 0.0))
    wsafe = jnp.maximum(wsum, _TINY)
    coeff = jnp.where(mask, w, 0.0) / wsafe             # (K,)

    # receiver noise, referred through the channel inversion: n / (sqrt(eta)
    # * sum w).  eta = inf (no participant caps the budget: empty round or
    # all-zero deltas) means no finite-power transmission constrains the
    # noise referral — the update is exactly the noiseless sum (zero).
    scale = jnp.where(
        jnp.isfinite(eta) & (eta > 0.0),
        jnp.float32(noise_std) / (jnp.sqrt(eta) * wsafe),
        0.0,
    )
    noise = scale * jax.random.normal(key, (p,), jnp.float32)

    if use_pallas:
        return ota_aggregate_pallas(flat, coeff, noise)
    return jnp.einsum("k,kn->n", coeff, flat) + noise


@functools.partial(
    jax.jit,
    static_argnames=("pmax", "noise_std", "threshold", "use_pallas"),
)
def superpose_tree(
    deltas, gains_k, agg_w, key,
    *, pmax: float, noise_std: float, threshold: float,
    use_pallas: bool = False,
):
    """OTA aggregation of a client-stacked delta tree (leaves (K, ...)).

    THE shared aggregation operator: the batched engine and the scanned
    horizon call it inside their round body, the legacy oracle stacks its
    host-loop deltas and calls it directly — one jitted computation, so the
    three drivers apply bit-identical aggregation math to a given delta
    stack.  Flattens the tree to one (K, P) matrix first (eta depends on
    the *whole* payload's energy, not per-leaf), superposes, splits back.
    Returns the update tree (leaves shaped like ``deltas`` minus the K
    axis).
    """
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    k = leaves[0].shape[0]
    sizes = [int(np.prod(leaf.shape[1:])) for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(k, -1).astype(jnp.float32) for leaf in leaves], axis=1
    )
    out = superpose_flat(
        flat, gains_k, agg_w, key, pmax=pmax, noise_std=noise_std,
        threshold=threshold, use_pallas=use_pallas,
    )
    parts = jnp.split(out, np.cumsum(sizes)[:-1])
    return jax.tree_util.tree_unflatten(
        treedef,
        [part.reshape(leaf.shape[1:]) for part, leaf in zip(parts, leaves)],
    )
