"""MAPEL power allocation (paper §III-C) vs grid oracle + structure tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.core import power

NOISE = 1.6e-14
PMAX = 0.01


def _instance(k, seed):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, k)) + 1e-8
    w = rng.dirichlet(np.ones(k))
    return gains, w


def test_min_powers_closed_form_inverts_targets():
    """Eq. (13): minimal powers reproduce the requested z targets exactly."""
    gains = np.sort(_instance(3, 0)[0])[::-1]
    z = np.array([1.5, 2.0, 3.0])
    p = power.min_powers_for_targets(z, gains, NOISE)
    # recompute z from p
    for k in range(3):
        mu = np.sum(p[k:] * gains[k:] ** 2) + NOISE
        phi = np.sum(p[k + 1 :] * gains[k + 1 :] ** 2) + NOISE
        assert mu / phi == pytest.approx(z[k], rel=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 3), st.integers(0, 10_000))
def test_mapel_beats_or_matches_grid(k, seed):
    gains, w = _instance(k, seed)
    sol = power.mapel(gains, w, PMAX, NOISE, eps=1e-4)
    grid = power.grid_oracle(gains, w, PMAX, NOISE, points=15)
    # MAPEL should be within the grid's resolution of the optimum (and is
    # usually above the coarse grid value).
    assert sol.weighted_rate >= grid.weighted_rate * (1 - 2e-2)
    assert np.all(sol.powers <= PMAX * (1 + 1e-9))
    assert np.all(sol.powers >= -1e-12)


def test_mapel_single_user_max_power():
    gains, w = _instance(1, 3)
    sol = power.mapel(gains, np.ones(1), PMAX, NOISE)
    assert sol.powers[0] == pytest.approx(PMAX)


def test_weighted_rate_matches_noma_module():
    import jax.numpy as jnp

    from repro.core import noma

    gains, w = _instance(3, 5)
    p = np.random.default_rng(5).uniform(0, PMAX, 3)
    ours = power.weighted_rate(p, gains, w, NOISE)
    ref = float(
        noma.weighted_sum_rate(jnp.asarray(p), jnp.asarray(gains), jnp.asarray(w), NOISE)
    )
    assert ours == pytest.approx(ref, rel=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(0, 9999))
def test_mapel_batched_matches_sequential(k, seed):
    """The lockstep polyblock (schedulers' finalization path) is mapel()
    group-for-group — bit-identical powers, rates, iteration counts, gaps."""
    rng = np.random.default_rng(seed)
    groups = 5
    gains = np.abs(rng.normal(1e-6, 5e-7, (groups, k))) + 1e-8
    w = rng.dirichlet(np.ones(k), size=groups)
    batched = power.mapel_batched(gains, w, PMAX, NOISE, eps=1e-3)
    for i in range(groups):
        seq = power.mapel(gains[i], w[i], PMAX, NOISE, eps=1e-3)
        np.testing.assert_array_equal(batched.powers[i], seq.powers)
        assert batched.weighted_rates[i] == seq.weighted_rate
        assert batched.iterations[i] == seq.iterations
        assert batched.gaps[i] == seq.gap


def test_mapel_batched_empty():
    out = power.mapel_batched(np.zeros((0, 3)), np.zeros((0, 3)), PMAX, NOISE)
    assert out.powers.shape == (0, 3)
    assert out.weighted_rates.shape == (0,)


def test_mapel_batched_k1_closed_form_matches_sequential():
    """K=1 takes the closed-form branch in BOTH drivers: full power, the
    interference-free rate, zero iterations, zero gap — and the batched
    rows must equal the sequential solves bit for bit (same formula, no
    polyblock float drift to hide behind)."""
    rng = np.random.default_rng(11)
    gains = np.abs(rng.normal(1e-6, 5e-7, (4, 1))) + 1e-8
    w = rng.dirichlet(np.ones(1), size=4)
    batched = power.mapel_batched(gains, w, PMAX, NOISE, eps=1e-3)
    np.testing.assert_array_equal(batched.powers, np.full((4, 1), PMAX))
    np.testing.assert_array_equal(batched.iterations, np.zeros(4, dtype=int))
    np.testing.assert_array_equal(batched.gaps, np.zeros(4))
    for i in range(4):
        seq = power.mapel(gains[i], w[i], PMAX, NOISE, eps=1e-3)
        np.testing.assert_array_equal(batched.powers[i], seq.powers)
        assert batched.weighted_rates[i] == seq.weighted_rate


def test_mapel_batched_near_zero_gains_matches_sequential():
    """Gains at the numerical floor (deep-fade devices, ~1e-12 amplitude):
    the z targets collapse to ~1 and log2 terms to ~0, the regime where the
    projection bisections and back-substitutions are most cancellation-
    prone.  The lockstep driver must still walk the identical float path as
    the sequential solver — bit-equal powers, rates, iterations, gaps —
    including rows that MIX a healthy gain with near-dead ones."""
    rng = np.random.default_rng(13)
    gains = np.abs(rng.normal(1e-12, 5e-13, (5, 3))) + 1e-15
    gains[2, 0] = 1e-6            # one healthy device among the dead
    gains[4] = 1e-15              # a whole row at the floor
    w = rng.dirichlet(np.ones(3), size=5)
    batched = power.mapel_batched(gains, w, PMAX, NOISE, eps=1e-3)
    assert np.all(np.isfinite(batched.powers))
    assert np.all(batched.powers >= -1e-12)
    assert np.all(batched.powers <= PMAX * (1 + 1e-9))
    for i in range(5):
        seq = power.mapel(gains[i], w[i], PMAX, NOISE, eps=1e-3)
        np.testing.assert_array_equal(batched.powers[i], seq.powers)
        assert batched.weighted_rates[i] == seq.weighted_rate
        assert batched.iterations[i] == seq.iterations
        assert batched.gaps[i] == seq.gap


def test_mapel_gap_reported():
    gains, w = _instance(3, 7)
    sol = power.mapel(gains, w, PMAX, NOISE, eps=1e-3, max_iter=300)
    # either converged to the certificate gap or hit the vertex cap
    assert (0 <= sol.gap <= 1e-3) or sol.iterations >= 300


# --------------------------------------------------------------------------
# PowerAllocator: the promoted make_power_fn (solve / solve_batched)
# --------------------------------------------------------------------------

def test_power_allocator_mapel_matches_scalar_and_batched():
    alloc = power.make_power_allocator("mapel", PMAX, NOISE)
    g1, w1 = _instance(3, 21)
    g2, w2 = _instance(3, 22)
    np.testing.assert_array_equal(
        alloc.solve(g1, w1), power.mapel(g1, w1, PMAX, NOISE, eps=1e-3).powers
    )
    g_vk = np.stack([g1, g2])
    w_vk = np.stack([w1, w2])
    np.testing.assert_array_equal(
        alloc.solve_batched(g_vk, w_vk),
        power.mapel_batched(g_vk, w_vk, PMAX, NOISE, eps=1e-3).powers,
    )
    # batched rows == per-group scalar solves (the lockstep guarantee,
    # reachable through the allocator API)
    np.testing.assert_array_equal(alloc.solve_batched(g_vk, w_vk)[0],
                                  alloc.solve(g1, w1))


def test_power_allocator_max_mode_and_powerfn_compat():
    """The allocator must drop into legacy PowerFn call sites: callable and
    carrying a ``batched`` attribute."""
    alloc = power.make_power_allocator("max", PMAX, NOISE)
    g, w = _instance(3, 23)
    np.testing.assert_array_equal(alloc(g, w), np.full(3, PMAX))
    np.testing.assert_array_equal(
        alloc.batched(np.stack([g, g]), np.stack([w, w])),
        np.full((2, 3), PMAX),
    )
    assert alloc(g, w) is not None and callable(alloc.batched)


def test_power_allocator_unknown_mode_raises():
    with pytest.raises(ValueError, match="power mode"):
        power.make_power_allocator("psycho", PMAX, NOISE)
