"""LeNet-300-100: the paper's own model (§IV).

Fully-connected 784 -> 300 -> 100 -> 10 with ReLU; 266,610 parameters
(784*300+300 + 300*100+100 + 100*10+10), matching the paper's count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def schema(cfg=None, *, shards: int = 1):
    return {
        "fc1": {"w": ParamSpec((784, 300), (None, None)),
                "b": ParamSpec((300,), (None,), init="zeros")},
        "fc2": {"w": ParamSpec((300, 100), (None, None)),
                "b": ParamSpec((100,), (None,), init="zeros")},
        "fc3": {"w": ParamSpec((100, 10), (None, None)),
                "b": ParamSpec((10,), (None,), init="zeros")},
    }


def forward(params, x):
    """x: (B, 784) float32 -> logits (B, 10)."""
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["fc3"]["w"] + params["fc3"]["b"]


def loss_fn(params, batch):
    logits = forward(params, batch["x"])
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params, x, y):
    return jnp.mean((jnp.argmax(forward(params, x), axis=-1) == y).astype(jnp.float32))
