"""NOMA uplink with successive interference cancellation (paper §II-A).

The PS decodes the K superposed uplink signals strongest-received-power first.
With users sorted so that p_1 h_1^2 > p_2 h_2^2 > ... > p_K h_K^2 the SINR of
user k (Eq. 5) is

    gamma_k = p_k h_k^2 / (sum_{j>k} p_j h_j^2 + sigma^2)

and the last user sees only noise. Rates are spectral efficiencies
R_k = log2(1 + gamma_k) (Eq. 6); multiply by bandwidth for bit/s.

Everything here is pure jnp and differentiable in the powers, which the MAPEL
power-allocation verifier exploits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sic_order(powers: jax.Array, gains: jax.Array) -> jax.Array:
    """Return decode order (indices into the group, strongest first)."""
    rx = powers * gains**2
    return jnp.argsort(-rx)


def sinr(powers: jax.Array, gains: jax.Array, noise_power: float) -> jax.Array:
    """Per-user SINR under SIC decoding, in the *input* user order.

    powers, gains: (K,). Decoding is strongest-received first; each user is
    interfered only by users decoded after it.
    """
    rx = powers * gains**2                          # received powers (K,)
    order = jnp.argsort(-rx)                        # decode order
    rx_sorted = rx[order]
    # Interference for position k = sum of received powers of positions > k.
    tail = jnp.cumsum(rx_sorted[::-1])[::-1] - rx_sorted
    sinr_sorted = rx_sorted / (tail + noise_power)
    # Scatter back to input order.
    out = jnp.zeros_like(sinr_sorted)
    return out.at[order].set(sinr_sorted)


def rates(powers: jax.Array, gains: jax.Array, noise_power: float) -> jax.Array:
    """Spectral efficiency per user (bit/s/Hz), input order (Eq. 6)."""
    return jnp.log2(1.0 + sinr(powers, gains, noise_power))


def bit_budget(
    powers: jax.Array,
    gains: jax.Array,
    noise_power: float,
    bandwidth_hz: float,
    slot_seconds: float,
) -> jax.Array:
    """Allowable transmission bits c_k = R_k * B * t for each user (§II-B)."""
    return rates(powers, gains, noise_power) * bandwidth_hz * slot_seconds


def weighted_sum_rate(
    powers: jax.Array,
    gains: jax.Array,
    weights: jax.Array,
    noise_power: float,
) -> jax.Array:
    """Objective inner term  sum_k w_k R_k  for one NOMA group (Eq. 8a)."""
    return jnp.sum(weights * rates(powers, gains, noise_power))


def tdma_rates(powers: jax.Array, gains: jax.Array, noise_power: float) -> jax.Array:
    """Interference-free rates used by the TDMA baseline (each user alone)."""
    snr = powers * gains**2 / noise_power
    return jnp.log2(1.0 + snr)
