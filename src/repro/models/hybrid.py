"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``hybrid_attn_every`` mamba layers [arXiv:2411.15242].

Structure: ``n_sites`` super-blocks of (every x mamba2) followed by the
shared attention+MLP block (one weight set reused at every site — the
Zamba2 signature), plus a tail of remaining mamba layers. The outer scan
runs over sites with the shared block's weights closed over (not scanned),
so weight reuse is structural, not copied.

Simplification vs the released model (DESIGN.md §6): Zamba2 concatenates the
original embedding into the shared block input and uses per-site LoRA deltas;
we apply the shared block on the residual stream directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T
from repro.models.params import stacked


def sites_of(cfg):
    n_sites = cfg.num_layers // cfg.hybrid_attn_every
    tail = cfg.num_layers % cfg.hybrid_attn_every
    assert n_sites >= 1, "hybrid needs at least one shared-attn site"
    return n_sites, tail


def schema(cfg, *, shards: int = 16):
    n_sites, tail = sites_of(cfg)
    sch = {
        "embed": L.embedding_schema(cfg.padded_vocab, cfg.d_model, tie=cfg.tie_embeddings),
        "sites": stacked(stacked(M.block_schema(cfg), cfg.hybrid_attn_every), n_sites),
        "shared_attn": T.block_schema(cfg, shards=shards),
        "ln_f": L.rmsnorm_schema(cfg.d_model),
    }
    if tail:
        sch["tail"] = stacked(M.block_schema(cfg), tail)
    return sch


def _mamba_stack(params_stacked, x, cfg, caches, *, remat, decode, unroll=False):
    def body(x, xs):
        p_layer, st = xs
        if decode:
            y, new_st = M.mamba_decode_step(p_layer, x, cfg, st)
        else:
            y, new_st = M.mamba_block(p_layer, x, cfg, state=st)
        return x + y, new_st

    fn = jax.checkpoint(body) if (remat and caches is None) else body
    return jax.lax.scan(fn, x, (params_stacked, caches), unroll=unroll)


def forward(params, tokens, cfg, *, caches=None, kv_chunk: int = 1024,
            remat: bool = True, unroll: bool = False, **_):
    n_sites, tail = sites_of(cfg)
    x = L.embed(params["embed"], tokens)
    mspec = L.AttnMaskSpec(causal=True)
    decode = caches is not None and tokens.shape[1] == 1

    positions = None
    if caches is not None:
        positions = caches["attn"]["len"][0] + jnp.arange(tokens.shape[1])[None, :]

    shared = params["shared_attn"]

    def site_body(x, xs):
        p_site, site_caches = xs
        mamba_caches = None if caches is None else site_caches["mamba"]
        attn_cache = None if caches is None else site_caches["attn"]
        x, new_mamba = _mamba_stack(
            p_site, x, cfg, mamba_caches, remat=remat, decode=decode,
            unroll=unroll,
        )
        x, new_attn = T.transformer_block(
            shared, x, cfg, mspec=mspec, positions=positions,
            cache=attn_cache, kv_chunk=kv_chunk,
        )
        return x, {"mamba": new_mamba, "attn": new_attn}

    site_xs = {
        "mamba": None if caches is None else caches["mamba"],
        "attn": None if caches is None else caches["attn"],
    }
    x, new_site_caches = jax.lax.scan(site_body, x, (params["sites"], site_xs),
                                      unroll=unroll)

    new_tail = None
    if tail:
        tail_caches = None if caches is None else caches["tail"]
        x, new_tail = _mamba_stack(
            params["tail"], x, cfg, tail_caches, remat=remat, decode=decode,
            unroll=unroll,
        )

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, tie=cfg.tie_embeddings)
    new_caches = None
    if caches is not None:
        new_caches = {
            "mamba": new_site_caches["mamba"],
            "attn": new_site_caches["attn"],
        }
        if tail:
            new_caches["tail"] = new_tail
    return logits, new_caches


def loss_fn(params, batch, cfg, **kw):
    logits, _ = forward(params, batch["tokens"], cfg, **kw)
    return L.cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int, *, shards: int = 16):
    n_sites, tail = sites_of(cfg)
    mamba_one = M.init_state(cfg, batch)
    attn_one = L.init_attn_cache(cfg, batch, max_len, shards=shards)

    def rep(tree, n):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), tree
        )

    caches = {
        "mamba": rep(rep(mamba_one, cfg.hybrid_attn_every), n_sites),
        "attn": rep(attn_one, n_sites),
    }
    if tail:
        caches["tail"] = rep(mamba_one, tail)
    return caches


def decode_step(params, caches, tokens, cfg, *, kv_chunk: int = 4096,
                unroll: bool = False):
    return forward(params, tokens, cfg, caches=caches, kv_chunk=kv_chunk,
                   remat=False, unroll=unroll)
