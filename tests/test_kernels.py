"""Pallas kernel validation: interpret-mode vs pure-jnp oracle across
shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.dorefa import BLOCK_ROWS, LANE

SHAPES = [(17,), (128,), (4096,), (32768,), (100_001,), (3, 77, 11)]
DTYPES = [jnp.float32, jnp.bfloat16]
BITS = [1, 2, 4, 8, 16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [1, 4, 8])
def test_quantize_dequantize_matches_ref(shape, dtype, bits):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 0.3).astype(dtype)
    got = ops.quantize_dequantize(x, bits, use_pallas=True)
    scale = ops.max_abs_scale(x.reshape(-1))
    want = ref.quantize_dequantize_ref(x.astype(jnp.float32), bits, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6, atol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )
    assert got.dtype == x.dtype and got.shape == x.shape


@pytest.mark.parametrize("bits", BITS)
def test_pack_unpack_roundtrip(bits):
    x = jax.random.normal(jax.random.PRNGKey(1), (50_000,)) * 2.0
    codes, scale = ops.quantize_pack(x, bits, use_pallas=True)
    back = ops.unpack_dequantize(codes, scale, bits, x.size, use_pallas=True)
    want = ops.quantize_dequantize(x, bits)
    np.testing.assert_allclose(np.asarray(back), np.asarray(want), atol=1e-6)
    # codes bounded by +-(2^b - 1)
    assert int(jnp.max(jnp.abs(codes))) <= 2**bits - 1


@pytest.mark.parametrize("k", [1, 3, 8])
def test_weighted_aggregate_matches_ref(k):
    key = jax.random.PRNGKey(2)
    n = BLOCK_ROWS * LANE * 2
    xs = jax.random.normal(key, (k, n))
    packed = [ops.quantize_pack(xs[i], 4) for i in range(k)]
    codes = jnp.stack([c for c, _ in packed])
    scales = jnp.stack([s for _, s in packed])
    w = jax.random.dirichlet(key, jnp.ones(k))
    got = ops.weighted_aggregate(codes, scales, w, 4, use_pallas=True)
    want = ref.weighted_aggregate_ref(
        codes.reshape(k, -1), scales, w, 4
    ).reshape(got.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("n", [1, 17, 1000, BLOCK_ROWS * LANE + 5])
def test_weighted_aggregate_pallas_arbitrary_sizes(k, n):
    """Regression: the kernel used to assert rows % BLOCK_ROWS == 0 and
    lane == LANE; it must pad internally and slice, so any payload size
    (and K=1) works against the numpy oracle."""
    from repro.kernels.aggregate import weighted_aggregate_pallas

    rng = np.random.default_rng(n * 31 + k)
    codes = jnp.asarray(rng.integers(-15, 16, (k, n)), jnp.int32)
    scales = jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)
    w = jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32)
    got = weighted_aggregate_pallas(codes, scales, w, 4)
    want = np.sum(
        np.asarray(w)[:, None] * np.asarray(scales)[:, None]
        * np.asarray(codes, np.float64) / 15.0, axis=0,
    )
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_weighted_aggregate_pallas_empty_edges():
    """Zero-length payloads and K=0 return zeros instead of a 0-block grid
    error."""
    from repro.kernels.aggregate import weighted_aggregate_pallas

    out = weighted_aggregate_pallas(
        jnp.zeros((2, 0), jnp.int32), jnp.ones(2), jnp.ones(2), 4)
    assert out.shape == (0,)
    out = weighted_aggregate_pallas(
        jnp.zeros((0, 8), jnp.int32), jnp.zeros(0), jnp.zeros(0), 4)
    assert out.shape == (8,) and np.all(np.asarray(out) == 0.0)


def test_weighted_aggregate_pallas_per_client_levels():
    """``levels`` dequantizes each client with its own a_k = 2^{b_k} - 1
    (the batched FL engine's traced adaptive bit-widths); float32 codes are
    accepted since 2^32 - 1 levels overflow int32."""
    from repro.kernels.aggregate import weighted_aggregate_pallas

    rng = np.random.default_rng(7)
    x = rng.standard_normal((3, 500)).astype(np.float32)
    bits = np.array([2, 5, 8])
    a = (2.0 ** bits - 1).astype(np.float32)
    scales = np.abs(x).max(axis=1).astype(np.float32)
    codes = np.round(a[:, None] * np.clip(x / scales[:, None], -1, 1))
    w = np.asarray([0.2, 0.3, 0.5], np.float32)
    got = weighted_aggregate_pallas(
        jnp.asarray(codes, jnp.float32), jnp.asarray(scales), jnp.asarray(w),
        levels=jnp.asarray(a),
    )
    want = np.sum(w[:, None] * scales[:, None] * codes / a[:, None], axis=0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="exactly one of"):
        weighted_aggregate_pallas(
            jnp.asarray(codes, jnp.float32), jnp.asarray(scales),
            jnp.asarray(w), 4, levels=jnp.asarray(a),
        )


def test_aggregate_linearity():
    """Aggregation is linear: agg(w) ~ sum w_k dq_k (oracle identity)."""
    n = BLOCK_ROWS * LANE
    xs = [jax.random.normal(jax.random.PRNGKey(i), (n,)) for i in range(3)]
    packed = [ops.quantize_pack(x, 8) for x in xs]
    codes = jnp.stack([c for c, _ in packed])
    scales = jnp.stack([s for _, s in packed])
    w = jnp.asarray([0.2, 0.3, 0.5])
    agg = ops.weighted_aggregate(codes, scales, w, 8, use_pallas=True).reshape(-1)
    manual = sum(
        w[i] * ops.unpack_dequantize(codes[i], scales[i], 8, n) for i in range(3)
    )
    np.testing.assert_allclose(np.asarray(agg), np.asarray(manual), rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(1, 100_000), st.integers(0, 2**31 - 1))
def test_quantize_property_sweep(bits, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    got = ops.quantize_dequantize(x, bits, use_pallas=(n <= 40_000))
    scale = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(got))) <= scale + 1e-5
    assert float(jnp.max(jnp.abs(got - x))) <= scale / (2**bits - 1) + 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,g,d,s,vl", [
    (1, 1, 1, 128, 256, 256),
    (2, 2, 3, 128, 512, 300),
    (1, 4, 2, 64, 1024, 1),
    (3, 1, 8, 128, 256, 129),
])
def test_flash_decode_matches_ref(b, hkv, g, d, s, vl, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, hkv, g, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d)).astype(dtype)
    got = ops.flash_decode(q, k, v, jnp.asarray(vl), use_pallas=True)
    want = ref.flash_decode_ref(q, k, v, jnp.asarray(vl))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_decode_block_invariance():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, 2, 128))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1024, 2, 128))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1024, 2, 128))
    a = ops.flash_decode(q, k, v, jnp.asarray(700), use_pallas=True, block_s=256)
    b = ops.flash_decode(q, k, v, jnp.asarray(700), use_pallas=True, block_s=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
