"""User scheduling as maximum-weight independent set (paper §III).

Scheduling graph (§III-A): a vertex v = (S, t) is a K-subset S of devices
proposed for round t; there are C(M, K) * T vertices. Edges connect vertices
that violate
  C1 (device scheduled more than once): S_i and S_j share a device, t_i != t_j
  C2 (one group per round): t_i == t_j.
An independent set with T vertices is a complete schedule; vertex weight
w(v) = sum_{k in S} w_k R_k^t makes the MWIS the max-weighted-sum-rate
schedule (Eq. 9-10).

Three solvers:
  * ``literal_graph_schedule`` — the paper's Algorithm 2 (GWMIN greedy) on the
    explicitly constructed graph. Exact fidelity; exponential memory, use for
    M up to ~12.
  * ``lazy_greedy_schedule`` — provably equivalent to Algorithm 2 without
    materializing the graph (see note below); scales to the paper's M=300.
  * ``brute_force_schedule`` — exact optimum by enumeration (tests only).

Equivalence note (DESIGN.md §6.3): in the residual graph after any number of
GWMIN removals, the remaining vertex set is always {all K-subsets of unused
devices} x {remaining rounds}, and every vertex has the *same* degree
beta = (C(A,K)-1) + (T_rem-1) * (C(A,K) - C(A-K,K)), where A = #unused
devices. With uniform degrees, argmax_{v in Q} w(v)/(beta(v)+1) reduces to
argmax_v w(v) (the global max-weight vertex is always in Q since
sum_{u in J(v)} w(u)/(beta+1) <= beta*w(v)/(beta+1) + w(v)/(beta+1) = w(v)).
So Algorithm 2 == repeatedly take the max-weight (subset, round) among unused
devices and remaining rounds. ``tests/test_scheduling.py`` checks the two
produce identical schedules on instances where the literal graph fits.

Backends: ``lazy_greedy_schedule(backend="numpy")`` (default) walks rounds in
Python and scores each round's candidate batch with the numpy engine;
``backend="jax"`` runs the whole per-step argmax on device
(``repro.core.rates_jax.greedy_step``): the C(pool, K) subset enumeration is
built once as *positions* into a per-round candidate pool, and every greedy
step is a single jitted call that re-masks availability, re-ranks the pools,
scores the full (T, V, K) vertex tensor, and returns the argmax vertex.  The
two backends produce bit-identical schedules (same stable tie-breaking:
earliest round, lexicographically-first subset, ties in the pool ranking to
the lower device id); leftover tail groups smaller than K fall back to the
host path.  Power refinement with ``power_mode="mapel"`` is batched over all
selected groups at the end (``power.mapel_batched``) instead of solved
round-by-round.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core import power as power_lib
from repro.core import rates as rates_lib

PowerFn = Callable[[np.ndarray, np.ndarray], np.ndarray]
# (gains_K, weights_K) -> powers_K; may carry a ``batched`` attribute
# (gains_VK, weights_VK) -> powers_VK for vectorized candidate scoring.


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

def make_power_fn(mode: str, pmax: float, noise_power: float) -> PowerFn:
    """'max' -> everyone at p^max; 'mapel' -> optimal MLFP allocation.

    Both modes carry a ``batched`` attribute ((V, K) -> (V, K)) so candidate
    scoring and schedule finalization run one grouped call instead of a
    Python loop per group; MAPEL's is the lockstep polyblock
    (``power.mapel_batched``), which reproduces the sequential solver
    group-for-group.
    """
    if mode == "max":
        fn = lambda g, w: np.full(len(g), pmax)
        fn.batched = lambda g_vk, w_vk: np.full(np.shape(g_vk), pmax)
        return fn
    if mode == "mapel":
        fn = lambda g, w: power_lib.mapel(g, w, pmax, noise_power, eps=1e-3).powers
        fn.batched = lambda g_vk, w_vk: power_lib.mapel_batched(
            g_vk, w_vk, pmax, noise_power, eps=1e-3
        ).powers
        return fn
    raise ValueError(f"unknown power mode {mode!r}")


def _solo_proxy(gains, weights, pmax: float, noise_power: float) -> np.ndarray:
    """Pool-ranking proxy: weighted interference-free rate of each device
    alone.  Shared by the numpy per-round pool and the jax backend's
    precomputed (T, M) table — the backends' bit-equality rests on ranking
    from identical float64 values, so there is exactly one formula."""
    return weights * np.log2(1.0 + (pmax * gains**2) / noise_power)


def _batched_powers(power_fn: PowerFn, gains_vk, weights_vk) -> np.ndarray:
    """(V, K) powers for V candidate groups; row loop only for iterative
    allocators (MAPEL) that expose no vectorized form."""
    batched = getattr(power_fn, "batched", None)
    if batched is not None:
        return batched(gains_vk, weights_vk)
    return np.stack(
        [power_fn(g, w) for g, w in zip(gains_vk, weights_vk)]
    )


def score_subsets(
    subsets_vk: np.ndarray,
    t: int,
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    power_fn: PowerFn,
    noise_power: float,
) -> np.ndarray:
    """Weighted sum rate of every candidate group in one engine call.

    subsets_vk: (V, K) int array of device ids, one candidate K-subset per
    row, all proposed for round t. Replaces the seed's per-subset Python
    loop (one ``group_weighted_rate`` call per ``itertools.combinations``
    element) with a single (V, K) ``batched_weighted_rates`` evaluation.
    """
    if subsets_vk.size == 0:
        return np.zeros((len(subsets_vk),))
    g = gains_tm[t][subsets_vk]
    w = weights_m[subsets_vk]
    p = _batched_powers(power_fn, g, w)
    return rates_lib.batched_weighted_rates(p, g, w, noise_power)


def group_weighted_rate(
    subset: Sequence[int],
    t: int,
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    power_fn: PowerFn,
    noise_power: float,
):
    """Weighted sum rate (and powers, rates) of scheduling `subset` at round t."""
    idx = np.asarray(subset, dtype=np.intp)
    g = gains_tm[t, idx]
    w = weights_m[idx]
    p = power_fn(g, w)
    rates = rates_lib.sic_rates(p, g, noise_power)
    return float(np.sum(w * rates)), p, rates


def _rates(powers, gains, noise_power):
    """Thin wrapper kept for back-compat; the math lives in core.rates."""
    return rates_lib.sic_rates(powers, gains, noise_power)


@dataclasses.dataclass
class Schedule:
    """A complete schedule: device groups, powers and rates per round."""

    rounds: list            # list[T] of tuple[int, ...] device ids
    powers: list            # list[T] of np.ndarray (K,)
    rates: list             # list[T] of np.ndarray (K,) spectral efficiencies
    weighted_sum_rate: float
    method: str

    def scheduled_devices(self) -> set:
        return set(itertools.chain.from_iterable(self.rounds))

    def validate(self, num_devices: int, k: int):
        """Assert constraints C1/C2 hold."""
        seen = set()
        for grp in self.rounds:
            assert len(grp) <= k, "C2 violated"
            for d in grp:
                assert 0 <= d < num_devices
                assert d not in seen, "C1 violated"
                seen.add(d)
        return True


def _finalize(rounds, gains_tm, weights_m, power_fn, noise_power, method):
    """Powers/rates/weighted-sum for a complete schedule.

    Groups are batched by size and handed to the allocator in one call per
    size (for MAPEL this is the batched polyblock refinement over all T
    selected groups — the per-round loop it replaces solved each group
    separately).  Tail groups smaller than K (T*K > M horizons) and empty
    rounds batch among themselves.
    """
    num_rounds = len(rounds)
    powers, rates = [None] * num_rounds, [None] * num_rounds
    vals = np.zeros(num_rounds)
    by_size = {}
    for t, grp in enumerate(rounds):
        by_size.setdefault(len(grp), []).append(t)
    for kk, ts in sorted(by_size.items()):
        idx = np.array([rounds[t] for t in ts], dtype=np.intp).reshape(len(ts), kk)
        g = gains_tm[np.asarray(ts, dtype=np.intp)[:, None], idx]
        w = weights_m[idx]
        if kk == 0:
            p = np.zeros((len(ts), 0))
        else:
            p = _batched_powers(power_fn, g, w)
        r = rates_lib.sic_rates(p, g, noise_power)
        for row, t in enumerate(ts):
            powers[t] = p[row]
            rates[t] = r[row]
            vals[t] = float(np.sum(w[row] * r[row]))
    total = 0.0
    for t in range(num_rounds):    # accumulate in round order (reproducible)
        total += float(vals[t])
    return Schedule(list(map(tuple, rounds)), powers, rates, total, method)


# --------------------------------------------------------------------------
# Literal Algorithm 2 on the explicit scheduling graph
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SchedulingGraph:
    vertices: list          # list of (subset tuple, t)
    weights: np.ndarray     # (V,)
    adjacency: list         # list[V] of set[int]

    def degree(self, v: int) -> int:
        return len(self.adjacency[v])


def build_scheduling_graph(
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    k: int,
    power_fn: PowerFn,
    noise_power: float,
) -> SchedulingGraph:
    """Explicit graph with C(M,K)*T vertices (paper §III-A)."""
    num_rounds, num_devices = gains_tm.shape
    subsets = list(itertools.combinations(range(num_devices), k))
    vertices = [(subset, t) for t in range(num_rounds) for subset in subsets]
    subs_vk = np.array(subsets, dtype=np.intp).reshape(len(subsets), k)
    weights = np.concatenate(
        [
            score_subsets(subs_vk, t, gains_tm, weights_m, power_fn, noise_power)
            for t in range(num_rounds)
        ]
    )
    adjacency = [set() for _ in vertices]
    for i, (si, ti) in enumerate(vertices):
        set_i = set(si)
        for j in range(i + 1, len(vertices)):
            sj, tj = vertices[j]
            if ti == tj or set_i & set(sj):
                adjacency[i].add(j)
                adjacency[j].add(i)
    return SchedulingGraph(vertices, weights, adjacency)


def gwmin_mwis(graph: SchedulingGraph) -> list:
    """Algorithm 2: greedy maximum-weight independent set (GWMIN).

    Returns selected vertex indices. J(v) = v and its neighbours; beta(v) the
    degree; Q = {v : w(v) >= sum_{u in J(v)} w(u)/(beta(u)+1)};
    v* = argmax_{v in Q} w(v)/(beta(v)+1).
    """
    alive = set(range(len(graph.vertices)))
    adj = {v: set(graph.adjacency[v]) for v in alive}
    w = graph.weights
    selected = []
    while alive:
        beta = {v: len(adj[v]) for v in alive}
        q = []
        for v in alive:
            closed = adj[v] | {v}
            thresh = sum(w[u] / (beta[u] + 1) for u in closed)
            if w[v] >= thresh - 1e-12:
                q.append(v)
        if not q:  # theoretical fallback; GWMIN guarantees Q nonempty
            q = list(alive)
        v_star = max(q, key=lambda v: w[v] / (beta[v] + 1))
        selected.append(v_star)
        remove = adj[v_star] | {v_star}
        alive -= remove
        for v in alive:
            adj[v] -= remove
    return selected


def literal_graph_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Paper-exact Algorithm 2 (explicit graph). Small M only."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    graph = build_scheduling_graph(gains_tm, weights_m, k, power_fn, noise_power)
    chosen = gwmin_mwis(graph)
    num_rounds = gains_tm.shape[0]
    rounds = [()] * num_rounds
    for v in chosen:
        subset, t = graph.vertices[v]
        rounds[t] = subset
    return _finalize(
        rounds, gains_tm, weights_m, power_fn, noise_power, "literal-gwmin"
    )


# --------------------------------------------------------------------------
# Lazy (scalable) equivalent of Algorithm 2
# --------------------------------------------------------------------------

def _best_subset_for_round(
    t, avail, gains_tm, weights_m, k, power_fn, noise_power, candidate_pool, pmax
):
    """Best K-subset of `avail` for round t.

    Exact when len(avail) is small; otherwise enumerates subsets of the
    ``candidate_pool`` strongest devices (by singleton weighted rate), which
    preserves the greedy's behaviour in practice (weak devices never enter
    the argmax group). All C(pool, K) candidates are scored in a single
    batched rate-engine call; ties keep the lexicographically first subset,
    matching the seed's sequential strict-improvement loop.
    """
    avail = np.asarray(sorted(avail))
    if len(avail) > candidate_pool:
        # Stable sort so proxy ties keep the lower device id — the rule the
        # jax backend's masked ranking uses, keeping the backends identical.
        solo = _solo_proxy(gains_tm[t, avail], weights_m[avail], pmax, noise_power)
        keep = avail[np.argsort(-solo, kind="stable")[:candidate_pool]]
    else:
        keep = avail
    kk = min(k, len(keep))
    subs_vk = np.array(
        list(itertools.combinations(sorted(keep.tolist()), kk)), dtype=np.intp
    ).reshape(-1, kk)
    if len(subs_vk) == 0:
        return -np.inf, None
    vals = score_subsets(subs_vk, t, gains_tm, weights_m, power_fn, noise_power)
    i_best = int(np.argmax(vals))
    return float(vals[i_best]), tuple(subs_vk[i_best].tolist())


def _greedy_rounds_numpy(
    gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax,
    *, rounds=None, avail=None, remaining=None,
):
    """Host-path greedy selection loop (also the jax backend's tail path).

    Mutates/returns ``rounds`` (list[T] of tuples); ``avail``/``remaining``
    default to the full device/round sets so the jax driver can hand over
    mid-schedule state when fewer than K devices remain.
    """
    num_rounds, num_devices = gains_tm.shape
    if rounds is None:
        rounds = [()] * num_rounds
    if avail is None:
        avail = set(range(num_devices))
    if remaining is None:
        remaining = set(range(num_rounds))
    while remaining and len(avail) > 0:
        # max-weight vertex across all remaining rounds
        best = (-np.inf, None, None)
        for t in sorted(remaining):
            val, sub = _best_subset_for_round(
                t, avail, gains_tm, weights_m, k, search_fn, noise_power,
                candidate_pool, pmax,
            )
            if val > best[0]:
                best = (val, sub, t)
        _, subset, t = best
        if subset is None:
            break
        rounds[t] = subset
        avail -= set(subset)
        remaining.discard(t)
    return rounds


def _greedy_rounds_jax(
    gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax
):
    """Device-path greedy selection: one jitted argmax call per step.

    The C(pool, K) enumeration is built once as positions into the
    per-round candidate pool; each step ``rates_jax.greedy_step`` re-masks
    availability and scores the whole (T, V, K) vertex tensor on device.
    Runs under x64 so scores (and therefore argmax tie-breaking) line up
    with the float64 host path.  Once fewer than K devices remain (T*K > M
    horizons), the host loop finishes the leftover smaller groups — the
    enumeration is fixed-K, and those tail steps are O(C(K-1, kk)) cheap.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import rates_jax

    num_rounds, num_devices = gains_tm.shape
    pool = int(min(candidate_pool, num_devices))
    kk = min(k, pool)
    subs_pos = np.array(
        list(itertools.combinations(range(pool), kk)), dtype=np.int32
    ).reshape(-1, kk)
    # Pool-ranking proxy, computed with the *host* engine so both backends
    # rank candidate pools from identical float64 values.
    solo_tm = _solo_proxy(gains_tm, weights_m[None, :], pmax, noise_power)
    rounds = [()] * num_rounds
    with jax.experimental.enable_x64():
        jg = jnp.asarray(gains_tm, jnp.float64)
        jw = jnp.asarray(weights_m, jnp.float64)
        jsolo = jnp.asarray(solo_tm, jnp.float64)
        jsubs = jnp.asarray(subs_pos)
        avail = jnp.ones(num_devices, bool)
        done = jnp.zeros(num_rounds, bool)
        avail_count = num_devices
        steps = 0
        while steps < num_rounds and avail_count >= kk:
            val, t_star, sub_ids, avail, done = rates_jax.greedy_step(
                jg, jw, jsolo, jsubs, avail, done,
                pool=pool, pmax=float(pmax), noise_power=float(noise_power),
            )
            if not bool(val > -jnp.inf):
                break
            rounds[int(t_star)] = tuple(int(d) for d in np.asarray(sub_ids))
            avail_count -= kk
            steps += 1
        avail_np = np.asarray(avail)
        done_np = np.asarray(done)
        avail_host = set(np.flatnonzero(avail_np).tolist())
        remaining_host = set(np.flatnonzero(~done_np).tolist())
    if avail_host and remaining_host:
        _greedy_rounds_numpy(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool,
            pmax, rounds=rounds, avail=avail_host, remaining=remaining_host,
        )
    return rounds


def lazy_greedy_schedule(
    gains_tm,
    weights_m,
    k,
    *,
    power_mode="max",
    pmax=0.01,
    noise_power=1e-13,
    candidate_pool=24,
    backend="numpy",
) -> Schedule:
    """Graph-free Algorithm 2 (see module docstring for the equivalence).

    ``candidate_pool`` bounds the per-round enumeration to the pool of
    strongest devices; the batched rate engine scores all C(pool, K)
    candidates in one call, so pools of 24-64 are cheap (the seed's
    per-subset loop capped practical pools at ~16).

    ``backend="jax"`` moves the per-step argmax itself onto the device path
    (one jitted (T, V, K) scoring call per greedy step; see module
    docstring) and produces bit-identical schedules; use it for M >> 300.

    With power_mode="mapel" the subset *search* runs at max power and MAPEL
    refines only the selected groups — batched over all T groups in one
    ``power.mapel_batched`` call at finalization (a MAPEL solve per
    candidate subset — the literal paper procedure — is O(C(pool,K)) solves
    per round and only reorders near-ties). literal_graph_schedule keeps
    the paper's exact per-vertex power allocation."""
    search_fn = make_power_fn("max", pmax, noise_power)
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    if backend == "numpy":
        rounds = _greedy_rounds_numpy(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax
        )
    elif backend == "jax":
        rounds = _greedy_rounds_jax(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax
        )
    else:
        raise ValueError(f"unknown scheduling backend {backend!r}")
    return _finalize(rounds, gains_tm, weights_m, power_fn, noise_power, "lazy-gwmin")


# --------------------------------------------------------------------------
# Exact optimum (tests only)
# --------------------------------------------------------------------------

def brute_force_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Enumerate every feasible schedule (C1/C2) — exponential, tests only."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    subsets = list(itertools.combinations(range(num_devices), k))
    subs_vk = np.array(subsets, dtype=np.intp).reshape(len(subsets), k)
    vals = {
        (s, t): v
        for t in range(num_rounds)
        for s, v in zip(
            subsets,
            score_subsets(subs_vk, t, gains_tm, weights_m, power_fn, noise_power),
        )
    }
    best_total, best_assign = -np.inf, None

    def rec(t, used, total, assign):
        nonlocal best_total, best_assign
        if t == num_rounds:
            if total > best_total:
                best_total, best_assign = total, list(assign)
            return
        for s in subsets:
            if used & set(s):
                continue
            assign.append(s)
            rec(t + 1, used | set(s), total + vals[(s, t)], assign)
            assign.pop()

    rec(0, set(), 0.0, [])
    return _finalize(
        best_assign, gains_tm, weights_m, power_fn, noise_power, "brute-force"
    )


# --------------------------------------------------------------------------
# Baseline schedulers (paper §IV comparisons and ref [6] policies)
# --------------------------------------------------------------------------

def random_schedule(
    rng: np.random.Generator, gains_tm, weights_m, k,
    *, power_mode="max", pmax=0.01, noise_power=1e-13,
) -> Schedule:
    """Random scheduling respecting C1 (each device at most once)."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    perm = rng.permutation(num_devices)
    rounds = [tuple(perm[t * k : (t + 1) * k].tolist()) for t in range(num_rounds)]
    return _finalize(rounds, gains_tm, weights_m, power_fn, noise_power, "random")


def round_robin_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Round robin: fixed device order, K per round (ref [6] policy).

    When T*K > M the tail rounds get the leftover devices (possibly none)
    instead of emitting out-of-range device ids — C1 still holds and every
    id stays < num_devices.
    """
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    rounds = [
        tuple(range(min(t * k, num_devices), min((t + 1) * k, num_devices)))
        for t in range(num_rounds)
    ]
    return _finalize(rounds, gains_tm, weights_m, power_fn, noise_power, "round-robin")


def proportional_fair_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Per round, pick the K best unused devices by instantaneous gain.

    When every device has been used before the horizon ends (T*K > M) the
    remaining rounds get empty groups, like round-robin's tail — the intp
    dtype keeps the empty-``avail`` gather legal (a bare ``np.array([])`` is
    float64 and rejects fancy indexing).
    """
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    used = set()
    rounds = []
    for t in range(num_rounds):
        avail = np.array(
            [d for d in range(num_devices) if d not in used], dtype=np.intp
        )
        order = avail[np.argsort(-gains_tm[t, avail])]
        grp = tuple(order[:k].tolist())
        used |= set(grp)
        rounds.append(grp)
    return _finalize(
        rounds, gains_tm, weights_m, power_fn, noise_power, "proportional-fair"
    )
