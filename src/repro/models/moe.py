"""Mixture-of-experts decoder (mixtral, llama4-scout).

GShard-style dispatch: tokens are grouped (group size ``MOE_GROUP``), the
router picks top-k experts per token, each expert processes a fixed-capacity
buffer (capacity_factor over the uniform share), overflow tokens drop to the
residual path. Dispatch/combine are one-hot einsums — the TPU-native
formulation (dense MXU work + all-to-all under pjit) rather than a
CUDA-style scatter/gather (DESIGN.md §3).

llama4-scout: top-1 routing + always-on shared expert, block-local attention
for long context. mixtral: top-2 routing + sliding-window attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import ParamSpec, stacked

MOE_GROUP = 2048  # tokens per dispatch group (bounds one-hot memory)


def moe_schema(cfg):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    sch = {
        "router": ParamSpec((d, e), ("embed", "expert_in")),
        "wi_gate": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wi_up": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.moe_shared_expert:
        sch["shared"] = L.mlp_schema(d, f)
    return sch


def block_schema(cfg, *, shards: int = 16):
    return {
        "ln1": L.rmsnorm_schema(cfg.d_model),
        "attn": L.attention_schema(cfg, shards=shards),
        "ln2": L.rmsnorm_schema(cfg.d_model),
        "moe": moe_schema(cfg),
    }


def schema(cfg, *, shards: int = 16):
    return {
        "embed": L.embedding_schema(cfg.padded_vocab, cfg.d_model, tie=cfg.tie_embeddings),
        "layers": stacked(block_schema(cfg, shards=shards), cfg.num_layers),
        "ln_f": L.rmsnorm_schema(cfg.d_model),
    }


def moe_block(p, x, cfg):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    e = cfg.num_experts
    k = cfg.experts_per_token
    group = min(MOE_GROUP, s)
    g = (b * s) // group
    xg = x.reshape(g, group, d)

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)                    # (g, t, e)

    cap = int(group * k * cfg.capacity_factor / e) + 1

    dispatch = jnp.zeros((g, group, e, cap), L.COMPUTE_DTYPE)
    combine = jnp.zeros((g, group, e, cap), jnp.float32)
    masked = probs
    expert_mass = jnp.zeros((g, e), jnp.float32)
    # Buffer slots claimed by earlier choice ranks: rank-r positions must be
    # OFFSET by the counts of ranks < r, or a token's 2nd choice lands in
    # the same (expert, slot) as another token's 1st choice — the inputs
    # then SUM in the buffer and both tokens read a corrupted expert output
    # (caught by test_moe_decode_exact_without_drops: outputs depended on
    # sequence length).
    taken = jnp.zeros((g, 1, e), jnp.float32)
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)                      # (g, t)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # (g, t, e)
        w = jnp.sum(masked * onehot, axis=-1)                  # (g, t)
        # position of each token within its expert's buffer
        pos = (jnp.cumsum(onehot, axis=1) + taken) * onehot - 1.0  # (g, t, e)
        keep = (pos >= 0) & (pos < cap)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        slot = pos_oh * keep[..., None].astype(jnp.float32)    # (g, t, e, cap)
        dispatch = dispatch + slot.astype(L.COMPUTE_DTYPE)
        combine = combine + slot * w[:, :, None, None]
        expert_mass = expert_mass + jnp.mean(onehot, axis=1)
        taken = taken + jnp.sum(onehot, axis=1, keepdims=True)
        masked = masked * (1.0 - onehot)

    # Load-balance auxiliary loss (Switch-style): E * <fraction> . <prob mass>
    frac = expert_mass / k
    mean_prob = jnp.mean(probs, axis=1)
    aux = e * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))

    xin = jnp.einsum("gtd,gtec->gecd", xg.astype(L.COMPUTE_DTYPE), dispatch)
    gate = jnp.einsum("gecd,edf->gecf", xin, p["wi_gate"].astype(L.COMPUTE_DTYPE))
    up = jnp.einsum("gecd,edf->gecf", xin, p["wi_up"].astype(L.COMPUTE_DTYPE))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(L.COMPUTE_DTYPE) * up
    eout = jnp.einsum("gecf,efd->gecd", act, p["wo"].astype(L.COMPUTE_DTYPE))
    y = jnp.einsum(
        "gecd,gtec->gtd", eout, combine.astype(L.COMPUTE_DTYPE)
    )

    out = y.reshape(b, s, d).astype(x.dtype)
    if cfg.moe_shared_expert:
        out = out + L.mlp_block(p["shared"], x)
    return out, aux


def moe_transformer_block(p, x, cfg, *, mspec, positions, cache, kv_chunk):
    h, new_cache = L.attention_block(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
        mask_spec=mspec, positions=positions, cache=cache, kv_chunk=kv_chunk,
    )
    x = x + h
    y, aux = moe_block(p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + y, new_cache, aux


def forward(
    params, tokens, cfg, *,
    caches=None, positions=None, kv_chunk: int = 1024, remat: bool = True,
    unroll: bool = False,
):
    x = L.embed(params["embed"], tokens)
    mspec = T.mask_spec(cfg)
    if positions is None and caches is not None:
        positions = caches["len"][0] + jnp.arange(tokens.shape[1])[None, :]

    def body(carry, xs):
        x, aux_sum = carry
        p_layer, cache = xs
        y, new_cache, aux = moe_transformer_block(
            p_layer, x, cfg, mspec=mspec, positions=positions,
            cache=cache, kv_chunk=kv_chunk,
        )
        return (y, aux_sum + aux), new_cache

    fn = jax.checkpoint(body) if (remat and caches is None) else body
    (x, aux), new_caches = jax.lax.scan(
        fn, (x, jnp.zeros((), jnp.float32)), (params["layers"], caches),
        unroll=unroll,
    )
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, tie=cfg.tie_embeddings)
    return logits, new_caches, aux / cfg.num_layers


def loss_fn(params, batch, cfg, *, aux_coef: float = 0.01, **kw):
    logits, _, aux = forward(params, batch["tokens"], cfg, **kw)
    ce = L.cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)
    return ce + aux_coef * aux


init_cache = T.init_cache


def decode_step(params, caches, tokens, cfg, *, kv_chunk: int = 4096,
                unroll: bool = False):
    logits, new_caches, _ = forward(
        params, tokens, cfg, caches=caches, kv_chunk=kv_chunk, remat=False,
        unroll=unroll,
    )
    return logits, new_caches
