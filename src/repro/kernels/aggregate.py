"""Pallas kernel for the PS-side fused dequant + weighted aggregation.

Server aggregation (paper Algorithm 1 line 10): theta update is the weighted
sum of K dequantized client payloads. Fusing dequant+scale+sum keeps each
code tile in VMEM exactly once instead of K separate dequant passes +
K-way add in HBM.

Tiling: codes are flattened to (K, R, 128) with R padded up to a multiple of
BLOCK_ROWS (the pad is sliced back off, so arbitrary payload sizes work);
each grid step loads a (K, BLOCK_ROWS, 128) brick (K <= 16 in practice, so
the brick stays well under VMEM limits) and reduces over K in registers.

Two dequant modes: a single static ``bits`` (every client quantized alike,
the historical API) or a per-client ``levels`` vector a_k = 2^{b_k} - 1 for
the batched FL engine's traced adaptive bit-widths.  Codes may be int32
(packed payloads) or float32 (traced codes where b_k can reach 32 and
2^32 - 1 no longer fits an int32).

Transformer-scale payloads (10^6-10^8 params) additionally chunk over the
parameter axis: above ``chunk_elems`` per client, the flattened (K, N)
matrix is processed as a ``lax.map`` over (K, chunk_elems) slabs, so the
padded tile grid for the whole payload is never materialized at once
(benchmarks/payload_bench.py measures this against the XLA einsum —
BENCH_payload.json).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dorefa import BLOCK_ROWS, LANE

TILE_ELEMS = BLOCK_ROWS * LANE

DEFAULT_CHUNK_ELEMS = 64 * TILE_ELEMS   # ~2.1M elems (8.4 MB f32) per client
# Auto-chunk threshold: a multiple of the tile grid, deliberately larger
# than any LeNet-300-100 leaf (max 235,200 elems), so every pre-existing
# call site keeps tracing the identical unchunked program bit for bit.


def _aggregate_kernel(c_ref, coeff_ref, o_ref, *, k: int):
    # c_ref: (K, BLOCK_ROWS, LANE) codes; coeff_ref: (K,) scale*weight/a
    acc = jnp.zeros((c_ref.shape[1], c_ref.shape[2]), jnp.float32)
    for i in range(k):  # K is small and static: unrolled VPU adds
        acc = acc + c_ref[i, :, :].astype(jnp.float32) * coeff_ref[i]
    o_ref[...] = acc


def _aggregate_block(flat, coeff, *, interpret):
    """One padded-tile-grid pallas_call over a (K, n) slab; returns (n,)."""
    k, n = flat.shape
    pad = (-n) % TILE_ELEMS
    padded = jnp.pad(flat, ((0, 0), (0, pad)))
    tiles = padded.reshape(k, -1, LANE)
    rows = tiles.shape[1]
    grid = (rows // BLOCK_ROWS,)
    out = pl.pallas_call(
        functools.partial(_aggregate_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, BLOCK_ROWS, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(tiles, coeff)
    return out.reshape(-1)[:n]


def weighted_aggregate_pallas(
    codes: jax.Array,     # (K, ...) int32 or float32 codes, any trailing shape
    scales: jax.Array,    # (K,)
    weights: jax.Array,   # (K,)
    bits: int | None = None,
    *,
    levels: jax.Array | None = None,  # (K,) per-client a = 2^b - 1 (traced ok)
    interpret: bool = True,
    chunk_elems: int | None = None,
) -> jax.Array:
    """sum_k w_k * scale_k * codes_k / a_k, shaped like ``codes[0]``.

    Exactly one of ``bits`` (static, shared by all clients) or ``levels``
    (per-client, may be traced) selects the dequant divisor.  Payloads of
    any size are padded to the (BLOCK_ROWS, LANE) tile grid internally and
    the pad is sliced off the result; K = 1 and empty payloads are legal.

    ``chunk_elems`` (default :data:`DEFAULT_CHUNK_ELEMS`) caps the
    per-client slab a single pallas_call sees: payloads above it are
    reduced chunk by chunk under ``jax.lax.map``, so only one
    (K, chunk_elems) brick is tile-padded and resident at a time.  Chunk
    boundaries don't touch the math — each output element is still one
    K-term dot — so chunked and unchunked calls agree exactly.
    """
    if (bits is None) == (levels is None):
        raise ValueError("pass exactly one of bits= or levels=")
    k = codes.shape[0]
    out_shape = codes.shape[1:]
    n = 1
    for d in out_shape:
        n *= int(d)
    if k == 0 or n == 0:
        return jnp.zeros(out_shape, jnp.float32)
    if levels is None:
        levels = jnp.full((k,), float(2 ** int(bits) - 1), jnp.float32)
    coeff = (
        scales.astype(jnp.float32)
        * weights.astype(jnp.float32)
        / levels.astype(jnp.float32)
    )
    flat = codes.reshape(k, n)
    if chunk_elems is None:
        chunk_elems = DEFAULT_CHUNK_ELEMS
    chunk_elems = max(int(chunk_elems), TILE_ELEMS)
    if n <= chunk_elems:
        return _aggregate_block(
            flat, coeff, interpret=interpret
        ).reshape(out_shape)
    # Chunked path: pad the parameter axis to a chunk multiple ONCE, fold
    # it to (C, K, chunk) slabs, and let lax.map drive one block program
    # over them (compiled once, executed C times; peak live tile grid is
    # one chunk's, not the payload's).
    pad = (-n) % chunk_elems
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    slabs = flat.reshape(k, -1, chunk_elems).transpose(1, 0, 2)
    out = jax.lax.map(
        lambda slab: _aggregate_block(slab, coeff, interpret=interpret),
        slabs,
    )
    return out.reshape(-1)[:n].reshape(out_shape)


def _ota_kernel(c_ref, coeff_ref, n_ref, o_ref, *, k: int):
    # c_ref: (K, BLOCK_ROWS, LANE) raw updates; coeff_ref: (K,) masked
    # w_k / sum(w); n_ref: (BLOCK_ROWS, LANE) pre-scaled receiver noise
    acc = n_ref[...].astype(jnp.float32)
    for i in range(k):  # K is small and static: unrolled VPU adds
        acc = acc + c_ref[i, :, :].astype(jnp.float32) * coeff_ref[i]
    o_ref[...] = acc


def _ota_block(flat, coeff, noise, *, interpret):
    """One fused scale+superpose+denoise pallas_call over a (K, n) slab
    plus its (n,) noise strip; returns (n,)."""
    k, n = flat.shape
    pad = (-n) % TILE_ELEMS
    padded = jnp.pad(flat, ((0, 0), (0, pad)))
    tiles = padded.reshape(k, -1, LANE)
    noise_tiles = jnp.pad(noise, (0, pad)).reshape(-1, LANE)
    rows = tiles.shape[1]
    grid = (rows // BLOCK_ROWS,)
    out = pl.pallas_call(
        functools.partial(_ota_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, BLOCK_ROWS, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(tiles, coeff, noise_tiles)
    return out.reshape(-1)[:n]


def ota_aggregate_pallas(
    deltas: jax.Array,    # (K, ...) raw float client updates, any trailing shape
    coeff: jax.Array,     # (K,) masked OTA weights w_k / sum_A(w) (traced ok)
    noise: jax.Array,     # flattened receiver noise, already 1/sqrt(eta)-scaled
    *,
    interpret: bool = True,
    chunk_elems: int | None = None,
) -> jax.Array:
    """sum_k coeff_k * deltas_k + noise, shaped like ``deltas[0]``.

    The over-the-air receiver reduction (core/ota.py signal model): no
    dequant divisor — updates go over the air in analog, so the kernel
    fuses the FedAvg scaling, the superposition sum, and the additive
    receiver noise into one pass per tile.  ``noise`` must carry the full
    1/(sqrt(eta) * sum w) referral already (it is data, not a kernel
    parameter) and is flattened to the payload length.  The XLA einsum
    ``einsum("k,kn->n", coeff, flat) + noise`` is the equality oracle.

    K = 0 rounds degenerate to the bare noise floor; payloads above
    ``chunk_elems`` reuse the (K, chunk) slab layout of
    :func:`weighted_aggregate_pallas` with the noise strip chunked
    alongside, so only one brick is tile-padded at a time.
    """
    k = deltas.shape[0]
    out_shape = deltas.shape[1:]
    n = 1
    for d in out_shape:
        n *= int(d)
    if n == 0:
        return jnp.zeros(out_shape, jnp.float32)
    noise = noise.reshape(-1).astype(jnp.float32)
    if k == 0:
        return noise[:n].reshape(out_shape)
    flat = deltas.reshape(k, n)
    coeff = coeff.astype(jnp.float32)
    if chunk_elems is None:
        chunk_elems = DEFAULT_CHUNK_ELEMS
    chunk_elems = max(int(chunk_elems), TILE_ELEMS)
    if n <= chunk_elems:
        return _ota_block(
            flat, coeff, noise, interpret=interpret
        ).reshape(out_shape)
    pad = (-n) % chunk_elems
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    noise = jnp.pad(noise, (0, pad))
    slabs = flat.reshape(k, -1, chunk_elems).transpose(1, 0, 2)
    noise_slabs = noise.reshape(-1, chunk_elems)
    out = jax.lax.map(
        lambda sn: _ota_block(sn[0], coeff, sn[1], interpret=interpret),
        (slabs, noise_slabs),
    )
    return out.reshape(-1)[:n].reshape(out_shape)
