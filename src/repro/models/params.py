"""Parameter schema system: one declaration yields init + sharding specs.

A model describes its parameters as a pytree of :class:`ParamSpec` (shape +
logical axis names + initializer). From that single schema we derive:
  * ``init_params(schema, key)``  — materialized fp32 parameter pytree
  * ``logical_specs(schema)``     — same-structure pytree of logical-axis tuples,
                                    translated to PartitionSpec by repro.sharding.
  * ``abstract_params(schema)``   — ShapeDtypeStruct tree (dry-run, no allocation)

Logical axis vocabulary (resolved in repro/sharding/rules.py):
  "embed"   : d_model           -> unsharded (activations-stationary)
  "mlp"     : d_ff / heads*hd   -> tensor axis ("model")
  "heads"   : attention heads   -> tensor axis ("model")
  "kv"      : head_dim          -> unsharded
  "vocab"   : vocabulary        -> tensor axis ("model")
  "expert"  : MoE experts       -> tensor axis ("model")
  "fsdp"    : weight-shard axis -> data axis (parameter FSDP)
  "layers"  : scan-stacked layer dim -> unsharded
  None      : unsharded
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                     # logical names, len == len(shape)
    init: str = "normal"            # normal | zeros | ones | embed | ssm_a
    scale: Optional[float] = None   # stddev override for "normal"
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape) -> int:
    return int(np.prod(shape[:-1])) if len(shape) > 1 else int(shape[0])


def _materialize(spec: ParamSpec, key) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # Mamba2 A init: -uniform(1, 16) stored as log for stability.
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    # default: truncated-normal fan-in scaling
    std = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(_fan_in(spec.shape), 1))
    return (jax.random.truncated_normal(key, -3, 3, spec.shape, jnp.float32) * std).astype(dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(schema, key: jax.Array):
    """Materialize a schema pytree; each leaf gets a path-derived subkey.

    The fold constant is a CRC32 of the tree path, not Python's ``hash``:
    string hashing is salted per process (PYTHONHASHSEED), which made two
    runs of the same config initialize different models — every
    "reproducible from (inputs, config) alone" claim downstream rests on
    this digest being process-independent.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(schema, is_leaf=_is_spec)
    leaves = []
    for path, spec in flat:
        h = zlib.crc32(jax.tree_util.keystr(path).encode()) % (2**31)
        leaves.append(_materialize(spec, jax.random.fold_in(key, h)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def logical_specs(schema):
    """Pytree of logical-axis tuples matching the parameter pytree."""
    return jax.tree_util.tree_map(lambda s: s.axes, schema, is_leaf=_is_spec)


def abstract_params(schema):
    """ShapeDtypeStruct tree for .lower() without allocation."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        schema,
        is_leaf=_is_spec,
    )


def stacked(schema, n: int):
    """Prepend a scan-stacked layer dimension to every param in the subtree."""
    return jax.tree_util.tree_map(
        lambda s: dataclasses.replace(
            s, shape=(n, *s.shape), axes=("layers", *s.axes)
        ),
        schema,
        is_leaf=_is_spec,
    )
