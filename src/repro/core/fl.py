"""Federated learning runtime (paper Algorithm 1 + §IV simulation).

Faithful paper-scale FedAvg over the simulated NOMA cell:
  per round t:
    1. PS broadcasts theta^t (downlink timing model, no compression).
    2. The scheduler assigns K devices to round t.  Precomputed policies
       (MWIS schedule over the whole horizon, the §IV baselines) planned
       this before training started; online policies (``policy.online``,
       e.g. update-aware / age-fair) are called *here*, inside the loop,
       reading the previous rounds' update norms, participation counts,
       and realized rates from a ``scheduling.Observation``.
    3. Each scheduled device runs local SGD on its own non-iid shard and
       produces a model delta.
    4. The uplink rate of each device sets the bit budget c_k = R_k * B * t;
       the delta is DoReFa-quantized to b_k = floor(32 / r_k) bits (paper
       §II-B).  Under NOMA that is the SIC rate over the shared slot; under
       TDMA each device gets its interference-free rate over its own
       sub-slot (adaptive compression applies to both uplinks — comparing a
       compressed NOMA run against an uncompressed TDMA run would bias the
       Fig. 5 comparison).
    5. PS aggregates: theta^{t+1} = theta^t + sum_k w_k * dq(delta_k),
       w_k = |D_k| / sum_selected |D_k| (weighted FedAvg; see DESIGN.md §6
       on the paper's line-10 notation).
  Timing: NOMA round = t_slot + T_d; TDMA round = K * t_slot + T_d (§IV).

Two round-body engines implement steps 3-5, selected by
``FLConfig.fl_engine`` (this module owns the driver — scheduling, power,
budgets, timing, and logging are computed once and shared by both):

  * ``"legacy"`` — :func:`_legacy_round`: one host-level ``local_update``
    per scheduled device (K shard uploads + K jitted scans + K eager
    quantize passes + host ``tree_map`` aggregation per round).  Simple,
    transparent, and kept as the **oracle** the batched engine is pinned
    against (``tests/test_fl_engine.py``).
  * ``"batched"`` — :class:`repro.core.fl_engine.BatchedRoundEngine`: all
    M shards live on device in a ``ClientBank`` and the whole round body
    (K-row gather -> vmapped local SGD -> batched norms -> traced
    per-client adaptive quantization -> weighted aggregation) is **one
    jitted dispatch**.  Aggregation uses an XLA einsum by default or the
    fused dequant+aggregate Pallas kernel under ``FLConfig.use_pallas``.
    Same schedules, same bit-widths, accuracies equal to f32 tolerance;
    use it for large-M / large-K sweeps (BENCH_fl.json tracks the
    round-loop speedup).

The per-client SGD math itself lives in one place —
``fl_engine.sgd_epoch`` — which the legacy path jits per device and the
batched engine vmaps over the client axis.

The LLM-scale integration of the same compression lives in
repro/launch/train.py (quantized-DSGD inside the pjit'd step).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import channel as chan
from repro.core import compression, errors, fl_engine, noma, scheduling
from repro.core import ota as ota_lib
from repro.core import power as power_lib
from repro.core import quantization as qlib
from repro.data.client_bank import ClientBank, EvalBank, eval_sample_plan
from repro.models.fl_models import get_fl_model
from repro.utils.tree import tree_count


@dataclasses.dataclass
class RoundLog:
    round: int
    devices: tuple
    rates: np.ndarray            # spectral efficiency per scheduled device
    bits: np.ndarray             # quantization bit-widths used
    compression_ratios: np.ndarray
    test_accuracy: float
    wall_time_s: float           # cumulative simulated communication time


@dataclasses.dataclass
class FLResult:
    logs: list
    final_params: dict
    scheme: str

    def accuracies(self):
        return np.array([l.test_accuracy for l in self.logs])

    def times(self):
        return np.array([l.wall_time_s for l in self.logs])


# --------------------------------------------------------------------------
# Local training (the FLModel payload on device shards)
# --------------------------------------------------------------------------

# One jitted epoch per device — the same per-client math the batched engine
# vmaps; the single implementation lives in fl_engine.sgd_epoch (``unroll``
# is a scan parameter and ``model`` a hashable FLModel, hence static).
_sgd_epoch = jax.jit(fl_engine.sgd_epoch, static_argnames=("model", "unroll"))


def local_update(params, xs, ys, cfg: FLConfig, model):
    """Run local epochs; returns the model delta (new - old).

    Padding generalizes over the trailing feature/label shape: flat image
    rows with scalar labels, or (S,) token rows with (S,) shifted labels —
    pad positions always carry label -1, the shared validity convention.
    """
    n = len(xs)
    bs = cfg.batch_size
    n_batches = max(1, (n + bs - 1) // bs)
    pad = n_batches * bs - n
    xp = np.concatenate([xs, np.zeros((pad, *xs.shape[1:]), xs.dtype)])
    yp = np.concatenate([ys, np.full((pad, *ys.shape[1:]), -1, ys.dtype)])
    xb = jnp.asarray(xp.reshape(n_batches, bs, *xs.shape[1:]))
    yb = jnp.asarray(yp.reshape(n_batches, bs, *ys.shape[1:]))
    new = params
    for _ in range(cfg.local_epochs):
        new = _sgd_epoch(new, xb, yb, cfg.learning_rate, model=model)
    return jax.tree_util.tree_map(lambda a, b: a - b, new, params)


def _legacy_round(
    params, devs, budgets, agg_w, dataset, shards, cfg: FLConfig, payload,
    *, need_norms: bool, model, ota=None,
):
    """The per-device host round body (steps 3-5), kept as the oracle.

    One ``local_update`` + quantize pass per scheduled device, host
    ``tree_map`` aggregation.  Returns ``(params, bits_used, ratios,
    norms)`` — the same contract as ``BatchedRoundEngine.run_round``,
    including its ``ota`` dict (gains/key/pmax): under the OTA uplink the
    per-device deltas go over the air unquantized and the host stacks them
    into the SAME shared aggregation operator the batched engine calls
    (:func:`repro.core.ota.superpose_tree`), so the three drivers apply
    bit-identical OTA aggregation math to a given delta stack.
    """
    deltas, bits_used, ratios, norms = [], [], [], []
    for j, d in enumerate(devs):
        idx = shards[d]
        delta = local_update(
            params, dataset.x_train[idx], dataset.y_train[idx], cfg, model
        )
        if need_norms:
            # the policies' norm signal is the raw local update, taken
            # before quantization (Amiri et al. rank by what the device
            # computed, not by what the channel let through); policies
            # that never read obs.update_norms skip the per-device
            # reduction + host sync entirely
            norms.append(_tree_l2(delta))
        if cfg.compression == "adaptive":
            # NOMA: SIC rate over the shared slot; TDMA: interference-free
            # rate over the device's own sub-slot. Both budgets are in
            # ``budgets`` — quantizing only the NOMA uplink would bias
            # the Fig. 5 comparison in TDMA's favour.
            b = int(qlib.adaptive_bits(payload, budgets[j]))
            delta = compression.encode_decode_tree(
                delta, b, paper_exact=cfg.paper_exact_range
            )
            bits_used.append(b)
            ratios.append(float(qlib.compression_ratio(payload, budgets[j])))
        else:
            bits_used.append(32)
            ratios.append(1.0)
        deltas.append(delta)

    if deltas and ota is not None:
        # over-the-air: stack the host-loop deltas client-major and let the
        # shared superposition operator aggregate (FLConfig already forced
        # compression='none', so the deltas above are raw)
        stacked = jax.tree_util.tree_map(
            lambda *ds: jnp.stack([jnp.asarray(d) for d in ds]), *deltas
        )
        update = ota_lib.superpose_tree(
            stacked,
            jnp.asarray(np.asarray(ota["gains"]), jnp.float32),
            jnp.asarray(np.asarray(agg_w), jnp.float32),
            jnp.asarray(ota["key"]),
            pmax=float(ota["pmax"]), noise_std=float(cfg.ota_noise),
            threshold=float(cfg.ota_threshold),
            use_pallas=bool(cfg.use_pallas),
        )
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, update)
    elif deltas:
        update = jax.tree_util.tree_map(
            lambda *ds: sum(w * d for w, d in zip(agg_w, ds)), *deltas
        )
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, update)
    # else: empty round (T*K > M schedules legitimately produce empty
    # tail groups) — no uplink, no aggregation.
    return params, bits_used, ratios, norms


# --------------------------------------------------------------------------
# Scheduling front-end
# --------------------------------------------------------------------------

def policy_config(cell: chan.CellConfig, cfg: FLConfig) -> scheduling.PolicyConfig:
    """PolicyConfig from the FL settings + the cell physics."""
    return scheduling.PolicyConfig(
        group_size=cfg.group_size,
        power_mode=cfg.power_mode,
        pmax=cell.max_power_w,
        noise_power=cell.noise_power_w,
        backend=cfg.scheduler_backend,
        ota_noise=cfg.ota_noise,
        seed=cfg.seed,
    )


def make_schedule(
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    cell: chan.CellConfig,
    cfg: FLConfig,
    policy: "scheduling.SchedulerPolicy | None" = None,
) -> scheduling.Schedule:
    """One-shot schedule via the policy registry (string if/elif retired).

    ``policy`` lets a caller that already resolved ``cfg.scheduler`` (e.g.
    ``run_federated_learning``) reuse the instance.  For online policies
    this drives ``select_round`` with rate/participation feedback only (no
    FL state outside the training loop) — the live path in
    :func:`run_federated_learning` is the real deal.
    """
    if policy is None:
        policy = scheduling.get_policy(cfg.scheduler)
    return scheduling.build_schedule(
        policy, gains_tm, weights_m, policy_config(cell, cfg)
    )


def _round_physics(devs, powers_t, rates, t, gains, cell, uplink, dl_time):
    """Uplink rates, bit budgets, and wall time of one scheduled round.

    The single owner of the §IV timing/budget rules, shared by the
    per-round host loop and the scanned-horizon packer — the scan-vs-
    per-round equality of rates, budgets and times holds by construction.
    Returns ``(rates, budgets, round_time)``; ``rates``/``budgets`` are
    (len(devs),) float64.
    """
    if uplink == "tdma":
        # each device alone in its sub-slot, interference-free
        p = powers_t
        g = gains[t, list(devs)]
        rates = np.asarray(
            noma.tdma_rates(jnp.asarray(p), jnp.asarray(g), cell.noise_power_w)
        )
        slot = cell.slot_seconds  # each scheduled device gets a full slot
        budgets = rates * cell.bandwidth_hz * slot
        # airtime = one sub-slot per *scheduled* device: empty/partial
        # T*K > M tail rounds must not be charged the full K sub-slots
        # (that skewed the Fig. 5 time axis against TDMA tails)
        round_time = len(devs) * cell.slot_seconds + dl_time
    else:
        # noma and ota share this branch: both spend ONE shared uplink slot
        # per non-empty round (the analog superposition *is* a simultaneous
        # transmission — that shared-slot airtime is OTA's whole appeal).
        # The SIC rates/budgets are still logged for OTA runs as the
        # digital-equivalent capacity of the same slot (nothing downstream
        # quantizes to them: compression='none' is enforced).
        rates = np.asarray(rates)
        budgets = rates * cell.bandwidth_hz * cell.slot_seconds
        # the shared uplink slot is only spent when someone transmits —
        # empty T*K > M tail rounds cost downlink only (mirrors the TDMA
        # per-device sub-slot accounting above)
        uplink_time = cell.slot_seconds if devs else 0.0
        round_time = uplink_time + dl_time
    return rates, budgets, round_time


def _agg_weights(sizes, devs) -> np.ndarray:
    """FedAvg weights w_k = |D_k| / sum_selected |D_k| — one owner so both
    drivers (and both engines) aggregate with identical host-float64
    values."""
    raw_w = [sizes[d] for d in devs]
    return np.asarray(raw_w) / max(sum(raw_w), 1.0)


def _tree_l2(tree) -> float:
    """||tree||_2 over all leaves (the update-aware policies' norm signal).

    The squared dots accumulate on device; the single ``float()`` at the end
    is the only host sync (this runs per scheduled device per live round).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    return float(jnp.sqrt(sum(jnp.vdot(leaf, leaf) for leaf in leaves)))


# --------------------------------------------------------------------------
# Main simulation
# --------------------------------------------------------------------------

def run_federated_learning(
    dataset,
    shards: list,
    cell: chan.CellConfig,
    cfg: FLConfig,
    *,
    uplink: Optional[str] = None,    # "noma" | "tdma" | "ota"; None = cfg.uplink
    schedule: Optional[scheduling.Schedule] = None,
    eval_every: int = 1,
    progress: Optional[Callable[[RoundLog], None]] = None,
) -> FLResult:
    """Simulate the full FL process; returns per-round logs.

    dataset: repro.data.mnist_like.Dataset; shards: per-device index lists.

    ``uplink`` defaults to ``cfg.uplink`` and an explicit argument
    overrides it (re-validated against the config combos either way —
    ``ota.check_uplink``).  Under ``"ota"`` the round's aggregate is the
    noisy analog superposition (core/ota.py) instead of the digital
    decode-and-average.

    ``cfg.horizon = "scan"`` delegates to :func:`run_horizon_scanned`
    (the whole horizon as one device program — precomputed schedules and
    traced-protocol online policies alike; config validation already
    rejected the online policies that cannot trace); this host loop is
    the per-round driver every scanned path is equality-pinned against.
    """
    uplink = cfg.uplink if uplink is None else uplink
    ota_lib.check_uplink(
        uplink, compression=cfg.compression, topk=cfg.topk,
        power_mode=cfg.power_mode,
    )
    if cfg.horizon == "scan":
        return run_horizon_scanned(
            dataset, shards, cell, cfg, uplink=uplink, schedule=schedule,
            eval_every=eval_every, progress=progress,
        )
    key = jax.random.PRNGKey(cfg.seed)
    model = get_fl_model(cfg.model)
    params = model.init(key)
    payload = tree_count(params) * 32  # I: full-precision payload bits

    sizes = np.array([len(s) for s in shards], dtype=np.float64)
    weights = sizes / sizes.sum()

    # Round-body engine: "batched" folds steps 3-5 into one jitted dispatch
    # per round over a device-resident ClientBank; None selects the legacy
    # per-device host loop (the oracle — see module docstring).
    engine = None
    if cfg.fl_engine == "batched":
        engine = fl_engine.BatchedRoundEngine(
            dataset, shards, cfg, payload, model=model
        )

    # channel realizations for the whole horizon
    dist = chan.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(
        chan.sample_round_channels(jax.random.fold_in(key, 2), dist, cell,
                                   cfg.num_rounds)
    )

    # Scheduling: precomputed policies (and caller-supplied schedules) fix
    # the whole horizon now; online policies run live inside the round loop.
    policy = obs = policy_state = allocator = None
    if schedule is None:
        policy = scheduling.get_policy(cfg.scheduler)
        if getattr(policy, "online", False):
            pcfg = policy_config(cell, cfg)
            policy_state = policy.init_state(gains, weights, pcfg)
            obs = scheduling.Observation.initial(cell.num_devices)
            allocator = power_lib.make_power_allocator(
                cfg.power_mode, cell.max_power_w, cell.noise_power_w
            )
        else:
            # one owner for precomputed construction (validated inside
            # build_schedule with the policy's own C1 expectation),
            # reusing the instance resolved above
            schedule = make_schedule(gains, weights, cell, cfg, policy=policy)
            policy = None
    else:
        # Caller-supplied schedule: its own allow_revisits flag (set by
        # build_schedule from the producing policy, or by the caller for a
        # hand-rolled revisiting schedule) decides C1 strictness.
        schedule.validate(cell.num_devices, cfg.group_size)

    # Downlink broadcast time on the large-scale gain only: the paper's
    # Fig. 5 time scale (35 rounds in ~10-22 s) implies a fading-free
    # downlink; with per-round Rayleigh draws the worst faded user's T_d
    # dominates both schemes and masks the NOMA/TDMA uplink gap.
    dl_gains = chan.large_scale_gain(dist, cell)
    dl_time = float(chan.downlink_time_seconds(payload, dl_gains, cell))

    # OTA receiver-noise keys for the whole horizon — the same host
    # precompute the scanned driver packs, so the two drivers draw
    # bit-identical noise per round
    ota_keys = (
        ota_lib.horizon_keys(cfg.seed, cfg.num_rounds)
        if uplink == "ota" else None
    )

    if engine is None:   # the batched engine evaluates through its EvalBank
        x_test = jnp.asarray(dataset.x_test)
        y_test = jnp.asarray(dataset.y_test)
        # bound methods are fresh objects per attribute access, so
        # jax.jit(model.accuracy) here would recompile every run; the
        # engine's module-level jit (model as a static arg) caches properly
        acc_fn = functools.partial(fl_engine._eval_full, model=model)

    logs = []
    t_wall = 0.0
    for t in range(cfg.num_rounds):
        if policy is not None:   # live mode: select with FL-state feedback
            group, policy_state = policy.select_round(t, policy_state, obs)
            devs = tuple(int(d) for d in group)
            scheduling.validate_group(
                devs, cell.num_devices, cfg.group_size,
                label=f"round-{t} group from policy {policy.name!r}",
            )
            powers_t, rates = scheduling.finalize_round(
                devs, t, gains, weights, allocator, cell.noise_power_w
            )
        else:
            devs = schedule.rounds[t]
            powers_t = schedule.powers[t]
            rates = schedule.rates[t]  # spectral efficiency (bit/s/Hz)
        rates, budgets, round_time = _round_physics(
            devs, powers_t, rates, t, gains, cell, uplink, dl_time
        )
        agg_w = _agg_weights(sizes, devs)
        need_norms = policy is not None and getattr(policy, "needs_norms", True)
        ota_round = None
        if ota_keys is not None and devs:
            ota_round = dict(
                gains=gains[t, list(devs)], key=ota_keys[t],
                pmax=float(cell.max_power_w),
            )
        if engine is not None:
            params, bits_used, ratios, norms = engine.run_round(
                params, devs, budgets, agg_w, need_norms=need_norms,
                ota=ota_round,
            )
        else:
            params, bits_used, ratios, norms = _legacy_round(
                params, devs, budgets, agg_w, dataset, shards, cfg, payload,
                need_norms=need_norms, model=model, ota=ota_round,
            )
        # empty rounds (T*K > M schedules legitimately produce empty tail
        # groups) train/aggregate nothing; the wall clock still advances and
        # the round is still logged below.

        if policy is not None:
            # feed realized norms/rates back for the next select_round
            # (norms is empty when the policy declared needs_norms=False)
            obs = obs.record_round(t, devs, np.asarray(rates),
                                   norms if norms else None)

        t_wall += round_time
        # the final round is always evaluated: accuracies()[-1] must measure
        # the final model even when eval_every skips over num_rounds - 1
        do_eval = t % eval_every == 0 or t == cfg.num_rounds - 1
        if not do_eval:
            acc = logs[-1].test_accuracy
        elif engine is not None:
            # batched engine: eval through the EvalBank gather (sampled per
            # cfg.eval_sample; at 1.0 bit-identical to the legacy full eval)
            acc = engine.evaluate(params, t)
        else:
            acc = float(acc_fn(params, x_test, y_test))
        log = RoundLog(t, tuple(devs), np.asarray(rates), np.asarray(bits_used),
                       np.asarray(ratios), acc, t_wall)
        logs.append(log)
        if progress:
            progress(log)

    scheme = f"{uplink}/{cfg.scheduler}/{cfg.power_mode}/{cfg.compression}"
    return FLResult(logs, params, scheme)


# --------------------------------------------------------------------------
# Scanned horizons: the whole precomputed simulation as ONE device program
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _HorizonPlan:
    """Host-precomputed plan for one simulation instance (one seed).

    Everything the per-round driver computes on the host — model init,
    channel draws, schedule, rates, budgets, FedAvg weights, timing —
    packed into fixed-shape (T, K) tensors the scan consumes (zero-padded
    past each round's true group size; zero agg weights multiply the
    padding out of the aggregate exactly).
    """

    params0: dict                # freshly initialized model
    payload: int                 # I: full-precision payload bits
    schedule: scheduling.Schedule
    dev_tk: np.ndarray           # (T, K) int32 device ids, 0-padded
    ksizes: np.ndarray           # (T,) true per-round group sizes
    budgets_tk: np.ndarray       # (T, K) float64 uplink bit budgets, 0-padded
    aggw_tk: np.ndarray          # (T, K) float64 FedAvg weights, 0-padded
    gains_tk: np.ndarray         # (T, K) float32 channel amplitudes, 0-padded
                                 # (consumed only under the OTA uplink)
    noise_keys: np.ndarray       # (T, 2) uint32 OTA receiver-noise keys
    rates: list                  # per-round (k,) float64 uplink rates
    times: np.ndarray            # (T,) cumulative simulated wall clock
    eval_idx: "np.ndarray | None"  # (T, n) eval sample plan; None = full set


def _horizon_setup(dataset, shards, cell, cfg: FLConfig, uplink, schedule):
    """Host precompute for one scanned instance.

    Mirrors :func:`run_federated_learning`'s setup exactly — same PRNG
    folds, same schedule construction, same :func:`_round_physics` /
    :func:`_agg_weights` calls — so the two drivers simulate the identical
    system and the equality grid can demand identical schedules, budgets,
    rates and times.
    """
    key = jax.random.PRNGKey(cfg.seed)
    params = get_fl_model(cfg.model).init(key)
    payload = tree_count(params) * 32

    sizes = np.array([len(s) for s in shards], dtype=np.float64)
    weights = sizes / sizes.sum()

    dist = chan.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(
        chan.sample_round_channels(jax.random.fold_in(key, 2), dist, cell,
                                   cfg.num_rounds)
    )

    if schedule is None:
        policy = scheduling.get_policy(cfg.scheduler)
        if getattr(policy, "online", False):
            # Traced-protocol online policies are routed to the online
            # driver before this setup runs (run_horizon_scanned); any
            # online policy reaching a *precomputed* setup lacks that
            # protocol — guard direct calls with the pinned message
            # FLConfig raises at construction.
            raise ValueError(
                errors.ERR_SCAN_ONLINE_POLICY.format(scheduler=cfg.scheduler)
            )
        schedule = make_schedule(gains, weights, cell, cfg, policy=policy)
    else:
        schedule.validate(cell.num_devices, cfg.group_size)

    dl_gains = chan.large_scale_gain(dist, cell)
    dl_time = float(chan.downlink_time_seconds(payload, dl_gains, cell))

    T, K = cfg.num_rounds, cfg.group_size
    dev_tk = np.zeros((T, K), np.int32)
    ksizes = np.zeros(T, np.intp)
    budgets_tk = np.zeros((T, K), np.float64)
    aggw_tk = np.zeros((T, K), np.float64)
    gains_tk = np.zeros((T, K), np.float32)
    rates_list = []
    times = np.zeros(T, np.float64)
    t_wall = 0.0
    for t in range(T):
        devs = schedule.rounds[t]
        rates, budgets, round_time = _round_physics(
            devs, schedule.powers[t], schedule.rates[t], t, gains, cell,
            uplink, dl_time,
        )
        k = len(devs)
        ksizes[t] = k
        dev_tk[t, :k] = devs
        budgets_tk[t, :k] = budgets
        aggw_tk[t, :k] = _agg_weights(sizes, devs)
        gains_tk[t, :k] = gains[t, list(devs)]
        rates_list.append(rates)
        t_wall += round_time
        times[t] = t_wall

    # the same per-round noise keys the per-round driver folds on the host
    # (zeros are never consumed outside the OTA uplink, but packing them
    # unconditionally keeps the plan shape uplink-independent)
    noise_keys = ota_lib.horizon_keys(cfg.seed, T)

    eval_idx = eval_sample_plan(
        len(dataset.y_test), cfg.eval_sample, T, cfg.seed
    )
    return _HorizonPlan(params, payload, schedule, dev_tk, ksizes,
                        budgets_tk, aggw_tk, gains_tk, noise_keys,
                        rates_list, times, eval_idx)


def _horizon_statics(
    cfg: FLConfig, payload: int, eval_full: bool, cell, uplink,
) -> dict:
    """The static kwargs of the fl_engine horizon programs, from the config.

    The OTA statics are pinned to zeros outside the OTA uplink so a
    noma/tdma run never retraces when ota_noise/ota_threshold configs vary.
    """
    ota = uplink == "ota"
    return dict(
        lr=float(cfg.learning_rate), epochs=int(cfg.local_epochs),
        payload=int(payload), compress=cfg.compression == "adaptive",
        paper_exact=bool(cfg.paper_exact_range),
        use_pallas=bool(cfg.use_pallas), eval_full=bool(eval_full),
        model=get_fl_model(cfg.model), topk=float(cfg.topk),
        ota=ota,
        ota_noise=float(cfg.ota_noise) if ota else 0.0,
        ota_threshold=float(cfg.ota_threshold) if ota else 0.0,
        pmax=float(cell.max_power_w) if ota else 0.0,
    )


def _eval_mask(num_rounds: int, eval_every: int) -> np.ndarray:
    """(T,) bool: which rounds evaluate — same cadence rule as the host
    loop, final round always included."""
    return np.array(
        [t % eval_every == 0 or t == num_rounds - 1
         for t in range(num_rounds)]
    )


def _stack_plans(plans, bank, num_rounds):
    """Stack per-instance plans along a leading axis for vmap/shard_map.

    Returns ``(params_s, dev, bud, agg, gains, keys, eidx, eval_full, nb)``
    where ``nb`` is the sweep-wide max scheduled batch count (one static
    shape for every instance — the padding batches contribute exactly-zero
    gradients).
    """
    # stack on the host: jnp.stack compiles one concatenate program per
    # leaf shape AND per sweep width, so the XLA program count would vary
    # with the number of instances (the compile-count sanitizer tests pin
    # it constant); np.stack + device_put is a pure transfer
    params_s = jax.tree_util.tree_map(
        lambda *ls: jnp.asarray(np.stack([np.asarray(l) for l in ls])),
        *[p.params0 for p in plans]
    )
    dev = np.stack([p.dev_tk for p in plans])
    bud = np.stack([p.budgets_tk for p in plans])
    agg = np.stack([p.aggw_tk for p in plans])
    gains = np.stack([p.gains_tk for p in plans])
    keys = np.stack([p.noise_keys for p in plans])
    eval_full = plans[0].eval_idx is None
    if eval_full:
        # dummy single-row plan: the traced gather needs a concrete shape
        # even though eval_full short-circuits it out of the program
        eidx = np.zeros((len(plans), num_rounds, 1), np.int32)
    else:
        eidx = np.stack([p.eval_idx for p in plans])
    nb = max(
        max(bank.n_batches_for(g) for g in p.schedule.rounds) for p in plans
    )
    return params_s, dev, bud, agg, gains, keys, eidx, eval_full, nb


def _assemble_horizon_result(
    plan: _HorizonPlan, cfg: FLConfig, uplink, eval_mask, bits_tk, accs_t,
    final_params, progress=None, kept_tk=None,
) -> FLResult:
    """Per-round ``RoundLog`` list from the scan outputs + the host plan.

    Slices each round's (K,) scan row down to its true group size, rebuilds
    the compression ratios with the same helper the per-round engines call
    (honest sparse on-air ratios from ``kept_tk`` when the top-k stage is
    on), and forward-fills skipped-eval rounds' accuracy — the same logging
    contract :func:`run_federated_learning` produces, entry for entry.
    """
    logs = []
    acc_prev = None
    for t in range(cfg.num_rounds):
        k = int(plan.ksizes[t])
        bits_r = np.asarray(bits_tk[t, :k])
        if k == 0:
            ratios = np.zeros(0)
        elif cfg.compression == "adaptive" and cfg.topk < 1.0:
            ratios = compression.sparse_compression_ratio(
                plan.payload, np.asarray(kept_tk[t, :k]), bits_r,
                plan.payload // 32,
            )
        elif cfg.compression == "adaptive":
            ratios = np.asarray(
                qlib.compression_ratio(
                    plan.payload, np.asarray(plan.budgets_tk[t, :k], np.float64)
                ),
                np.float64,
            )
        else:
            ratios = np.ones(k)
        acc = float(accs_t[t]) if eval_mask[t] else acc_prev
        acc_prev = acc
        log = RoundLog(
            t, tuple(plan.schedule.rounds[t]), np.asarray(plan.rates[t]),
            bits_r, ratios, acc, float(plan.times[t]),
        )
        logs.append(log)
        if progress:
            progress(log)
    scheme = f"{uplink}/{cfg.scheduler}/{cfg.power_mode}/{cfg.compression}"
    return FLResult(logs, final_params, scheme)


def run_horizon_scanned(
    dataset,
    shards: list,
    cell: chan.CellConfig,
    cfg: FLConfig,
    *,
    uplink: Optional[str] = None,
    schedule: Optional[scheduling.Schedule] = None,
    eval_every: int = 1,
    progress: Optional[Callable[[RoundLog], None]] = None,
) -> FLResult:
    """One whole horizon as ONE device program.

    The tentpole driver behind ``cfg.horizon = "scan"``.  For precomputed
    schedules all host work (schedule, rates, budgets, weights, timing)
    happens up front in :func:`_horizon_setup`; training + quantization +
    aggregation + eval for all T rounds then run as a single ``lax.scan``
    dispatch (:func:`fl_engine.run_horizon`).  Online policies with the
    traced protocol route to :func:`_run_horizon_online` instead, which
    folds selection / power allocation / budget math into the same scan
    (one host sync per horizon).  Same logs as the per-round driver —
    identical schedules/bits/rates/times, f32-tolerance accuracies — which
    ``tests/test_fl_scan.py`` pins across the uplink x compression x
    policy grid (tests/test_ota.py adds the OTA row, where even the
    accuracies are bit-identical: both drivers feed the same noise keys;
    tests/test_policy_scan.py adds the online-policy grid).
    """
    uplink = cfg.uplink if uplink is None else uplink
    ota_lib.check_uplink(
        uplink, compression=cfg.compression, topk=cfg.topk,
        power_mode=cfg.power_mode,
    )
    if (
        schedule is None
        and scheduling.policy_is_online(cfg.scheduler)
        and scheduling.policy_is_traced(cfg.scheduler)
    ):
        if cfg.power_mode == "mapel":
            # mirror the FLConfig gate for direct calls: the polyblock
            # search is host-iterative and cannot run inside the scan
            raise ValueError(
                errors.ERR_SCAN_ONLINE_MAPEL.format(scheduler=cfg.scheduler)
            )
        return _run_horizon_online(
            dataset, shards, cell, cfg, uplink=uplink,
            eval_every=eval_every, progress=progress,
        )
    plan = _horizon_setup(dataset, shards, cell, cfg, uplink, schedule)
    bank = ClientBank.build(
        dataset.x_train, dataset.y_train, shards, cfg.batch_size
    )
    ebank = EvalBank.build(dataset.x_test, dataset.y_test)

    T = cfg.num_rounds
    eval_mask = _eval_mask(T, eval_every)
    eval_full = plan.eval_idx is None
    eidx = (np.zeros((T, 1), np.int32) if eval_full else plan.eval_idx)
    nb = max(bank.n_batches_for(g) for g in plan.schedule.rounds)

    final, bits_tk, kept_tk, accs_t = fl_engine.run_horizon(
        plan.params0,
        jnp.asarray(plan.dev_tk),
        jnp.asarray(plan.budgets_tk),
        jnp.asarray(plan.aggw_tk, jnp.float32),
        jnp.asarray(plan.gains_tk),
        jnp.asarray(plan.noise_keys),
        jnp.asarray(eval_mask),
        jnp.asarray(eidx),
        bank.xb, bank.yb, ebank.xe, ebank.ye,
        nb=int(nb),
        **_horizon_statics(cfg, plan.payload, eval_full, cell, uplink),
    )
    return _assemble_horizon_result(
        plan, cfg, uplink, eval_mask, np.asarray(bits_tk), np.asarray(accs_t),
        final, progress, kept_tk=np.asarray(kept_tk),
    )


# --------------------------------------------------------------------------
# Online-policy scanned horizons (the traced protocol's host driver)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _OnlinePlan:
    """Host precompute for one *online-policy* scanned instance.

    Unlike :class:`_HorizonPlan` there is no schedule to pack — selection
    happens inside the device program — so the plan carries the raw
    physics the traced policy and the post-sync log reconstruction both
    consume: the full (T, M) channel table, the data weights/sizes, and
    the policy's host aux (the f32 solo-rate table from ``init_traced``).
    """

    params0: dict                # freshly initialized model
    payload: int                 # I: full-precision payload bits
    gains: np.ndarray            # (T, M) float64 channel amplitudes
    weights: np.ndarray          # (M,) float64 data weights
    sizes: np.ndarray            # (M,) float64 shard sizes
    solo: np.ndarray             # (T, M) float32 policy aux (init_traced)
    noise_keys: np.ndarray       # (T, 2) uint32 OTA receiver-noise keys
    dl_time: float               # downlink broadcast seconds per round
    eval_idx: "np.ndarray | None"  # (T, n) eval sample plan; None = full set


def _traced_policy_config(cell, cfg: FLConfig) -> scheduling.PolicyConfig:
    """The PolicyConfig passed as a *static* jit argument to the online
    horizon programs: the fields no traced policy reads (seed, host
    scheduler backend) are pinned so program identity depends only on the
    physics (K, power mode, pmax, noise power, ota_noise) — a seed sweep
    reuses one compiled program."""
    return dataclasses.replace(
        policy_config(cell, cfg), seed=0, backend="numpy"
    )


def _online_statics(cfg: FLConfig, cell, uplink, policy) -> dict:
    """The online-only static kwargs of fl_engine.run_horizon_online
    (merged with :func:`_horizon_statics` at the call sites)."""
    return dict(
        scheduler=cfg.scheduler,
        pcfg=_traced_policy_config(cell, cfg),
        uplink=uplink,
        budget_scale=float(cell.bandwidth_hz) * float(cell.slot_seconds),
        need_norms=bool(getattr(policy, "needs_norms", True)),
    )


def _online_horizon_setup(dataset, shards, cell, cfg: FLConfig, uplink):
    """Host precompute for one online scanned instance.

    Mirrors :func:`run_federated_learning`'s setup exactly — same PRNG
    folds, same downlink model — and asks the policy's ``init_traced``
    for its host aux (the f64-computed, f32-cast solo-rate table), so the
    traced selection ranks the same numbers the per-round driver's
    ``select_round`` does.
    """
    key = jax.random.PRNGKey(cfg.seed)
    params = get_fl_model(cfg.model).init(key)
    payload = tree_count(params) * 32

    sizes = np.array([len(s) for s in shards], dtype=np.float64)
    weights = sizes / sizes.sum()

    dist = chan.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(
        chan.sample_round_channels(jax.random.fold_in(key, 2), dist, cell,
                                   cfg.num_rounds)
    )

    policy = scheduling.get_policy(cfg.scheduler)
    aux = policy.init_traced(gains, weights, policy_config(cell, cfg))

    dl_gains = chan.large_scale_gain(dist, cell)
    dl_time = float(chan.downlink_time_seconds(payload, dl_gains, cell))
    noise_keys = ota_lib.horizon_keys(cfg.seed, cfg.num_rounds)
    eval_idx = eval_sample_plan(
        len(dataset.y_test), cfg.eval_sample, cfg.num_rounds, cfg.seed
    )
    return _OnlinePlan(params, payload, gains, weights, sizes, aux["solo"],
                       noise_keys, dl_time, eval_idx)


def _finalize_online_plan(
    plan: _OnlinePlan, cfg: FLConfig, cell, uplink, dev_tk, mask_tk,
) -> _HorizonPlan:
    """Rebuild the host-f64 log tensors from the traced schedule.

    After the horizon's single ``device_get``, the realized (T, K) device
    ids + validity masks replay through the exact host calls the per-round
    driver makes — ``scheduling.finalize_round`` for powers/rates,
    :func:`_round_physics` for budgets/times — so the logged f64 values
    are bit-identical to per-round's *by construction* (the in-program f32
    rates priced the budgets the bits were computed from; the logs never
    read those).
    """
    allocator = power_lib.make_power_allocator(
        cfg.power_mode, cell.max_power_w, cell.noise_power_w
    )
    T, K = dev_tk.shape
    rounds, powers_l, rates_raw = [], [], []
    total = 0.0
    for t in range(T):
        devs = tuple(int(d) for d in dev_tk[t][mask_tk[t]])
        p_k, r_k = scheduling.finalize_round(
            devs, t, plan.gains, plan.weights, allocator, cell.noise_power_w
        )
        rounds.append(devs)
        powers_l.append(p_k)
        rates_raw.append(r_k)
        if devs:
            total += float(
                np.sum(plan.weights[np.asarray(devs, np.intp)] * r_k)
            )
    schedule = scheduling.Schedule(
        rounds, powers_l, rates_raw, total, cfg.scheduler, True
    )

    dev_out = np.zeros((T, K), np.int32)
    ksizes = np.zeros(T, np.intp)
    budgets_tk = np.zeros((T, K), np.float64)
    aggw_tk = np.zeros((T, K), np.float64)
    gains_tk = np.zeros((T, K), np.float32)
    rates_list = []
    times = np.zeros(T, np.float64)
    t_wall = 0.0
    for t in range(T):
        devs = rounds[t]
        rates, budgets, round_time = _round_physics(
            devs, powers_l[t], rates_raw[t], t, plan.gains, cell, uplink,
            plan.dl_time,
        )
        k = len(devs)
        ksizes[t] = k
        dev_out[t, :k] = devs
        budgets_tk[t, :k] = budgets
        aggw_tk[t, :k] = _agg_weights(plan.sizes, devs)
        gains_tk[t, :k] = plan.gains[t, list(devs)]
        rates_list.append(rates)
        t_wall += round_time
        times[t] = t_wall
    return _HorizonPlan(plan.params0, plan.payload, schedule, dev_out,
                        ksizes, budgets_tk, aggw_tk, gains_tk,
                        plan.noise_keys, rates_list, times, plan.eval_idx)


def _run_horizon_online(
    dataset,
    shards: list,
    cell: chan.CellConfig,
    cfg: FLConfig,
    *,
    uplink,
    eval_every: int = 1,
    progress: Optional[Callable[[RoundLog], None]] = None,
) -> FLResult:
    """One online-policy horizon as ONE device program, ONE host sync.

    The scan body selects devices (traced policy), allocates powers,
    prices budgets, trains, quantizes, aggregates and evaluates; the
    single ``jax.device_get`` below is the horizon's only host round-trip,
    after which :func:`_finalize_online_plan` rebuilds the f64 logs.
    """
    plan = _online_horizon_setup(dataset, shards, cell, cfg, uplink)
    bank = ClientBank.build(
        dataset.x_train, dataset.y_train, shards, cfg.batch_size
    )
    ebank = EvalBank.build(dataset.x_test, dataset.y_test)

    T = cfg.num_rounds
    eval_mask = _eval_mask(T, eval_every)
    eval_full = plan.eval_idx is None
    eidx = (np.zeros((T, 1), np.int32) if eval_full else plan.eval_idx)
    # the schedule is decided in-program: every device must fit the
    # gathered shape, so slice to the bank-wide max batch count (the
    # all-padding extra batches contribute exactly-zero gradients)
    nb = bank.n_batches_for(range(cell.num_devices))
    policy = scheduling.get_policy(cfg.scheduler)

    out = fl_engine.run_horizon_online(
        plan.params0,
        jnp.asarray(plan.solo),
        jnp.asarray(plan.gains, jnp.float32),
        jnp.asarray(plan.weights, jnp.float32),
        jnp.asarray(plan.sizes, jnp.float32),
        jnp.asarray(plan.noise_keys),
        jnp.asarray(eval_mask), jnp.asarray(eidx),
        bank.xb, bank.yb, ebank.xe, ebank.ye,
        nb=int(nb),
        **_online_statics(cfg, cell, uplink, policy),
        **_horizon_statics(cfg, plan.payload, eval_full, cell, uplink),
    )
    # ONE host sync for the whole horizon: schedule, bits, accuracies and
    # the final model come back together
    final, dev_tk, mask_tk, bits_tk, kept_tk, accs_t = jax.device_get(out)
    hplan = _finalize_online_plan(plan, cfg, cell, uplink, dev_tk, mask_tk)
    return _assemble_horizon_result(
        hplan, cfg, uplink, eval_mask, bits_tk, accs_t, final, progress,
        kept_tk=kept_tk,
    )


def _stack_online_plans(plans):
    """Host-stack per-instance online plans (same np.stack-not-jnp.stack
    rationale as :func:`_stack_plans`): returns
    ``(params_s, solo, gains_f32, keys, eidx, eval_full)``."""
    params_s = jax.tree_util.tree_map(
        lambda *ls: jnp.asarray(np.stack([np.asarray(l) for l in ls])),
        *[p.params0 for p in plans]
    )
    solo = np.stack([p.solo for p in plans])
    gains = np.stack([p.gains for p in plans]).astype(np.float32)
    keys = np.stack([p.noise_keys for p in plans])
    eval_full = plans[0].eval_idx is None
    if eval_full:
        T = plans[0].solo.shape[0]
        eidx = np.zeros((len(plans), T, 1), np.int32)
    else:
        eidx = np.stack([p.eval_idx for p in plans])
    return params_s, solo, gains, keys, eidx, eval_full


def _run_horizon_vmapped_online(
    dataset, shards, cell, cfg: FLConfig, seeds, uplink, eval_every,
) -> list:
    """Online-policy seed sweep: S traced horizons, one dispatch, one sync."""
    plans = [
        _online_horizon_setup(
            dataset, shards, cell, dataclasses.replace(cfg, seed=s), uplink
        )
        for s in seeds
    ]
    bank = ClientBank.build(
        dataset.x_train, dataset.y_train, shards, cfg.batch_size
    )
    ebank = EvalBank.build(dataset.x_test, dataset.y_test)

    T = cfg.num_rounds
    eval_mask = _eval_mask(T, eval_every)
    params_s, solo, gains, keys, eidx, eval_full = _stack_online_plans(plans)
    nb = bank.n_batches_for(range(cell.num_devices))
    policy = scheduling.get_policy(cfg.scheduler)

    out = fl_engine.run_horizon_online_vmapped(
        params_s,
        jnp.asarray(solo), jnp.asarray(gains),
        jnp.asarray(plans[0].weights, jnp.float32),
        jnp.asarray(plans[0].sizes, jnp.float32),
        jnp.asarray(keys), jnp.asarray(eval_mask), jnp.asarray(eidx),
        bank.xb, bank.yb, ebank.xe, ebank.ye,
        nb=int(nb),
        **_online_statics(cfg, cell, uplink, policy),
        **_horizon_statics(cfg, plans[0].payload, eval_full, cell, uplink),
    )
    final_s, dev_s, mask_s, bits_s, kept_s, accs_s = jax.device_get(out)
    results = []
    for s, plan in enumerate(plans):
        scfg = dataclasses.replace(cfg, seed=int(seeds[s]))
        hplan = _finalize_online_plan(
            plan, scfg, cell, uplink, dev_s[s], mask_s[s]
        )
        fp = jax.tree_util.tree_map(lambda l, s=s: jnp.asarray(l[s]), final_s)
        results.append(_assemble_horizon_result(
            hplan, scfg, uplink, eval_mask, bits_s[s], accs_s[s], fp,
            kept_tk=kept_s[s],
        ))
    return results


def _run_cell_sweep_online(
    dataset, shards, cell, cfg: FLConfig, C, S, uplink, eval_every,
    shards_n, inst_seeds,
) -> list:
    """Online-policy (cells x seeds) grid — traced horizons end to end.

    Same two execution strategies as :func:`run_cell_sweep`: a 1-shard
    mesh dispatches one :func:`fl_engine.run_horizon_online` program per
    instance (shared statics -> one compiled scan for the whole grid);
    multi-shard runs the stacked (C, S) program under ``shard_map``.
    """
    flat = [
        _online_horizon_setup(
            dataset, shards, cell,
            dataclasses.replace(cfg, seed=inst_seeds[c][s]), uplink,
        )
        for c in range(C)
        for s in range(S)
    ]
    bank = ClientBank.build(
        dataset.x_train, dataset.y_train, shards, cfg.batch_size
    )
    ebank = EvalBank.build(dataset.x_test, dataset.y_test)

    T = cfg.num_rounds
    eval_mask = _eval_mask(T, eval_every)
    params_f, solo, gains, keys, eidx, eval_full = _stack_online_plans(flat)
    nb = bank.n_batches_for(range(cell.num_devices))
    policy = scheduling.get_policy(cfg.scheduler)
    weights_j = jnp.asarray(flat[0].weights, jnp.float32)
    sizes_j = jnp.asarray(flat[0].sizes, jnp.float32)
    statics = dict(
        **_online_statics(cfg, cell, uplink, policy),
        **_horizon_statics(cfg, flat[0].payload, eval_full, cell, uplink),
    )

    def finish(i, c, s, final_np, dev_i, mask_i, bits_i, kept_i, accs_i):
        scfg = dataclasses.replace(cfg, seed=inst_seeds[c][s])
        hplan = _finalize_online_plan(
            flat[i], scfg, cell, uplink, dev_i, mask_i
        )
        fp = jax.tree_util.tree_map(jnp.asarray, final_np)
        return _assemble_horizon_result(
            hplan, scfg, uplink, eval_mask, bits_i, accs_i, fp,
            kept_tk=kept_i,
        )

    if shards_n == 1:
        emask_j = jnp.asarray(eval_mask)
        results = []
        for c in range(C):
            row = []
            for s in range(S):
                i = c * S + s
                out = fl_engine.run_horizon_online(
                    flat[i].params0,
                    jnp.asarray(solo[i]), jnp.asarray(gains[i]),
                    weights_j, sizes_j,
                    jnp.asarray(keys[i]), emask_j, jnp.asarray(eidx[i]),
                    bank.xb, bank.yb, ebank.xe, ebank.ye,
                    nb=int(nb), **statics,
                )
                final, dev_i, mask_i, bits_i, kept_i, accs_i = (
                    jax.device_get(out)
                )
                row.append(finish(
                    i, c, s, final, dev_i, mask_i, bits_i, kept_i, accs_i
                ))
            results.append(row)
        return results

    def cs(a):
        return a.reshape(C, S, *a.shape[1:])

    solo_cs, gains_cs = cs(solo), cs(gains)
    keys_cs, eidx_cs = cs(keys), cs(eidx)
    params_cs = jax.tree_util.tree_map(
        lambda l: l.reshape(C, S, *l.shape[1:]), params_f
    )
    pad = (-C) % shards_n
    if pad:
        solo_cs = np.concatenate([solo_cs, solo_cs[:pad]])
        gains_cs = np.concatenate([gains_cs, gains_cs[:pad]])
        keys_cs = np.concatenate([keys_cs, keys_cs[:pad]])
        eidx_cs = np.concatenate([eidx_cs, eidx_cs[:pad]])
        params_cs = jax.tree_util.tree_map(
            lambda l: jnp.concatenate([l, l[:pad]]), params_cs
        )

    out = fl_engine.run_horizon_online_sharded(
        params_cs,
        jnp.asarray(solo_cs), jnp.asarray(gains_cs), jnp.asarray(keys_cs),
        jnp.asarray(eval_mask), jnp.asarray(eidx_cs),
        weights_j, sizes_j,
        bank.xb, bank.yb, ebank.xe, ebank.ye,
        shards=shards_n, nb=int(nb), **statics,
    )
    final_cs, dev_cs, mask_cs, bits_cs, kept_cs, accs_cs = jax.device_get(out)
    results = []
    for c in range(C):
        row = []
        for s in range(S):
            fp = jax.tree_util.tree_map(
                lambda l, c=c, s=s: l[c, s], final_cs
            )
            row.append(finish(
                c * S + s, c, s, fp, dev_cs[c, s], mask_cs[c, s],
                bits_cs[c, s], kept_cs[c, s], accs_cs[c, s],
            ))
        results.append(row)
    return results


def run_horizon_vmapped(
    dataset,
    shards: list,
    cell: chan.CellConfig,
    cfg: FLConfig,
    *,
    seeds,
    uplink: Optional[str] = None,
    eval_every: int = 1,
) -> list:
    """A whole seed sweep — S independent scanned horizons, one dispatch.

    Each seed gets its own model init, channel draws, schedule and eval
    plan (``dataclasses.replace(cfg, seed=s)``); the client bank and test
    set are shared.  Returns one :class:`FLResult` per seed, in order —
    row s is the same program :func:`run_horizon_scanned` runs for that
    seed alone (the row-0 identity test pins this).
    """
    uplink = cfg.uplink if uplink is None else uplink
    ota_lib.check_uplink(
        uplink, compression=cfg.compression, topk=cfg.topk,
        power_mode=cfg.power_mode,
    )
    seeds = [int(s) for s in seeds]
    if not seeds:
        raise ValueError("seeds must be a non-empty sequence")
    if (scheduling.policy_is_online(cfg.scheduler)
            and scheduling.policy_is_traced(cfg.scheduler)):
        if cfg.power_mode == "mapel":
            raise ValueError(
                errors.ERR_SCAN_ONLINE_MAPEL.format(scheduler=cfg.scheduler)
            )
        return _run_horizon_vmapped_online(
            dataset, shards, cell, cfg, seeds, uplink, eval_every
        )
    plans = [
        _horizon_setup(
            dataset, shards, cell, dataclasses.replace(cfg, seed=s), uplink,
            None,
        )
        for s in seeds
    ]
    bank = ClientBank.build(
        dataset.x_train, dataset.y_train, shards, cfg.batch_size
    )
    ebank = EvalBank.build(dataset.x_test, dataset.y_test)

    T = cfg.num_rounds
    eval_mask = _eval_mask(T, eval_every)
    params_s, dev, bud, agg, gains, keys, eidx, eval_full, nb = _stack_plans(
        plans, bank, T
    )

    final_s, bits_stk, kept_stk, accs_st = fl_engine.run_horizon_vmapped(
        params_s,
        jnp.asarray(dev), jnp.asarray(bud), jnp.asarray(agg, jnp.float32),
        jnp.asarray(gains), jnp.asarray(keys),
        jnp.asarray(eval_mask), jnp.asarray(eidx),
        bank.xb, bank.yb, ebank.xe, ebank.ye,
        nb=int(nb),
        **_horizon_statics(cfg, plans[0].payload, eval_full, cell, uplink),
    )
    bits_np, accs_np = np.asarray(bits_stk), np.asarray(accs_st)
    kept_np = np.asarray(kept_stk)
    # unstack on the host for the same reason _stack_plans stacks there:
    # a traced l[s] compiles one dynamic_slice program per leaf shape per
    # sweep width, making the program count depend on the seed count
    final_np = jax.tree_util.tree_map(np.asarray, final_s)
    results = []
    for s, plan in enumerate(plans):
        fp = jax.tree_util.tree_map(lambda l, s=s: jnp.asarray(l[s]), final_np)
        results.append(_assemble_horizon_result(
            plan, dataclasses.replace(cfg, seed=seeds[s]), uplink, eval_mask,
            bits_np[s], accs_np[s], fp, kept_tk=kept_np[s],
        ))
    return results


def run_cell_sweep(
    dataset,
    shards: list,
    cell: chan.CellConfig,
    cfg: FLConfig,
    *,
    num_cells: int,
    seeds_per_cell: int = 1,
    uplink: Optional[str] = None,
    eval_every: int = 1,
    cell_shards: Optional[int] = None,
) -> list:
    """A (cells x seeds) grid of independent simulations, cell axis sharded.

    Each of the C * S instances is one scanned horizon with its own seed
    (``cfg.seed + c * seeds_per_cell + s`` — cells are just disjoint seed
    blocks of the same cell geometry; the draws differ, the physics config
    doesn't).

    With ``cell_shards > 1`` the stacked (C, S, ...) program runs under
    ``shard_map`` over :func:`repro.launch.mesh.cell_mesh` (clamped to
    ``jax.local_device_count()``), C padded up to a multiple of the mesh
    (repeating leading cells, unpadded on return) — each mesh device runs
    its own block of vmapped horizons in parallel.  On a trivial 1-device
    mesh (the default) the sweep instead dispatches one
    :func:`fl_engine.run_horizon` program per instance: all instances
    share the bank, the test set and ONE compiled scan (sweep-wide static
    shapes), and on a single core the sequential dispatches beat the
    double-vmapped program, whose instance-batched per-round gathers blow
    the cache with no parallelism in return (BENCH_cells.json).  Both
    paths produce identical results (pinned by tests/test_fl_scan.py).

    Returns ``results[c][s]`` :class:`FLResult` grids.
    """
    uplink = cfg.uplink if uplink is None else uplink
    ota_lib.check_uplink(
        uplink, compression=cfg.compression, topk=cfg.topk,
        power_mode=cfg.power_mode,
    )
    C, S = int(num_cells), int(seeds_per_cell)
    if C < 1 or S < 1:
        raise ValueError(f"need num_cells >= 1 and seeds_per_cell >= 1, "
                         f"got ({num_cells}, {seeds_per_cell})")
    shards_n = 1 if cell_shards is None else max(
        1, min(int(cell_shards), jax.local_device_count())
    )

    inst_seeds = [[cfg.seed + c * S + s for s in range(S)] for c in range(C)]
    if (scheduling.policy_is_online(cfg.scheduler)
            and scheduling.policy_is_traced(cfg.scheduler)):
        if cfg.power_mode == "mapel":
            raise ValueError(
                errors.ERR_SCAN_ONLINE_MAPEL.format(scheduler=cfg.scheduler)
            )
        return _run_cell_sweep_online(
            dataset, shards, cell, cfg, C, S, uplink, eval_every, shards_n,
            inst_seeds,
        )
    plans = [
        [
            _horizon_setup(
                dataset, shards, cell,
                dataclasses.replace(cfg, seed=inst_seeds[c][s]), uplink, None,
            )
            for s in range(S)
        ]
        for c in range(C)
    ]
    bank = ClientBank.build(
        dataset.x_train, dataset.y_train, shards, cfg.batch_size
    )
    ebank = EvalBank.build(dataset.x_test, dataset.y_test)

    T = cfg.num_rounds
    eval_mask = _eval_mask(T, eval_every)
    flat = [p for row in plans for p in row]
    params_f, dev, bud, agg, gains, keys, eidx, eval_full, nb = _stack_plans(
        flat, bank, T
    )
    statics = _horizon_statics(cfg, flat[0].payload, eval_full, cell, uplink)

    if shards_n == 1:
        # Single-device fast path: one run_horizon dispatch per instance.
        # Sweep-wide nb keeps the shapes static, so every instance reuses
        # the first one's compiled program.
        emask_j = jnp.asarray(eval_mask)
        results = []
        for c in range(C):
            row = []
            for s in range(S):
                i = c * S + s
                final, bits_tk, kept_tk, accs_t = fl_engine.run_horizon(
                    flat[i].params0,
                    jnp.asarray(dev[i]), jnp.asarray(bud[i]),
                    jnp.asarray(agg[i], jnp.float32),
                    jnp.asarray(gains[i]), jnp.asarray(keys[i]),
                    emask_j, jnp.asarray(eidx[i]),
                    bank.xb, bank.yb, ebank.xe, ebank.ye,
                    nb=int(nb), **statics,
                )
                row.append(_assemble_horizon_result(
                    flat[i], dataclasses.replace(cfg, seed=inst_seeds[c][s]),
                    uplink, eval_mask, np.asarray(bits_tk),
                    np.asarray(accs_t), final, kept_tk=np.asarray(kept_tk),
                ))
            results.append(row)
        return results

    def cs(a):
        return a.reshape(C, S, *a.shape[1:])

    dev, bud, agg = cs(dev), cs(bud), cs(agg)
    gains, keys, eidx = cs(gains), cs(keys), cs(eidx)
    params_cs = jax.tree_util.tree_map(
        lambda l: l.reshape(C, S, *l.shape[1:]), params_f
    )
    pad = (-C) % shards_n
    if pad:
        # shard_map needs C divisible by the mesh: repeat leading cells
        # (their results are sliced off below, so the waste is bounded by
        # shards - 1 duplicate cell programs)
        dev = np.concatenate([dev, dev[:pad]])
        bud = np.concatenate([bud, bud[:pad]])
        agg = np.concatenate([agg, agg[:pad]])
        gains = np.concatenate([gains, gains[:pad]])
        keys = np.concatenate([keys, keys[:pad]])
        eidx = np.concatenate([eidx, eidx[:pad]])
        params_cs = jax.tree_util.tree_map(
            lambda l: jnp.concatenate([l, l[:pad]]), params_cs
        )

    final_cs, bits_cstk, kept_cstk, accs_cst = fl_engine.run_horizon_sharded(
        params_cs,
        jnp.asarray(dev), jnp.asarray(bud), jnp.asarray(agg, jnp.float32),
        jnp.asarray(gains), jnp.asarray(keys),
        jnp.asarray(eval_mask), jnp.asarray(eidx),
        bank.xb, bank.yb, ebank.xe, ebank.ye,
        shards=shards_n, nb=int(nb), **statics,
    )
    bits_np = np.asarray(bits_cstk)[:C]
    kept_np = np.asarray(kept_cstk)[:C]
    accs_np = np.asarray(accs_cst)[:C]
    results = []
    for c in range(C):
        row = []
        for s in range(S):
            fp = jax.tree_util.tree_map(
                lambda l, c=c, s=s: l[c, s], final_cs
            )
            row.append(_assemble_horizon_result(
                plans[c][s],
                dataclasses.replace(cfg, seed=inst_seeds[c][s]), uplink,
                eval_mask, bits_np[c, s], accs_np[c, s], fp,
                kept_tk=kept_np[c, s],
            ))
        results.append(row)
    return results
