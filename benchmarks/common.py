"""Shared benchmark scaffolding: the paper-world builder + CSV emission."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.config import FLConfig
from repro.core import channel
from repro.data import dirichlet_partition, make_mnist_like


@dataclasses.dataclass
class World:
    dataset: object
    cell: channel.CellConfig
    shards: list


def build_world(*, num_devices: int, num_samples: int = 6000, seed: int = 0) -> World:
    ds = make_mnist_like(num_samples=num_samples, seed=seed)
    cell = channel.CellConfig(num_devices=num_devices)
    shards = dirichlet_partition(ds.y_train, num_devices, seed=seed)
    return World(ds, cell, shards)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived (benchmarks/run.py contract)."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))
