"""Gradient pytree codec (paper Algorithm 1 uplink path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import quantization as q


def _tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (37, 11)) * 0.1,
        "b": jax.random.normal(k2, (5,)) * 0.01,
        "nested": {"w2": jax.random.normal(k3, (130,)) * 2.0},
    }


def test_payload_bits():
    tree = _tree(jax.random.PRNGKey(0))
    assert C.payload_bits(tree) == (37 * 11 + 5 + 130) * 32


def test_encode_decode_matches_fused_qdq():
    tree = _tree(jax.random.PRNGKey(1))
    enc = C.encode_tree(tree, 6)
    dec = C.decode_tree(enc)
    fused = C.encode_decode_tree(tree, 6)
    for a, b in zip(jax.tree_util.tree_leaves(dec),
                    jax.tree_util.tree_leaves(fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_encoded_size_accounting():
    tree = _tree(jax.random.PRNGKey(2))
    n = 37 * 11 + 5 + 130
    enc = C.encode_tree(tree, 6)
    assert enc.total_bits == n * 7 + 3 * 32  # (b+1) bits/elem + scale/tensor
    assert enc.total_bits < C.payload_bits(tree)


def test_adaptive_bits_for_budget():
    tree = _tree(jax.random.PRNGKey(3))
    payload = C.payload_bits(tree)
    assert int(C.adaptive_bits_for_budget(tree, payload)) == 32
    assert int(C.adaptive_bits_for_budget(tree, payload / 4)) == 8
    assert int(C.adaptive_bits_for_budget(tree, 1.0)) == 1


def test_paper_exact_range_clips():
    tree = {"w": jnp.asarray([0.5, 2.0, -3.0])}
    out = C.encode_decode_tree(tree, 8, paper_exact=True)["w"]
    # values outside [-1, 1] clip under the paper's fixed range
    assert float(out[1]) == pytest.approx(1.0, abs=1e-2)
    assert float(out[2]) == pytest.approx(-1.0, abs=1e-2)
    # per-tensor scaling (our extension) preserves them
    out2 = C.encode_decode_tree(tree, 8)["w"]
    assert float(out2[1]) == pytest.approx(2.0, abs=0.05)


def test_quantized_aggregation_error_small_at_8bit():
    """End-to-end: aggregate of quantized deltas close to exact average."""
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    w = [0.5, 0.3, 0.2]
    exact = jax.tree_util.tree_map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)
    qtrees = [C.encode_decode_tree(t, 8) for t in trees]
    approx = jax.tree_util.tree_map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *qtrees)
    for a, b in zip(jax.tree_util.tree_leaves(exact),
                    jax.tree_util.tree_leaves(approx)):
        rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
        assert rel < 0.02


def test_error_feedback_identity():
    """EF invariant: q_t + r_t == g_t + r_{t-1} exactly (no signal lost)."""
    from repro.core.compression import error_feedback_optimizer
    from repro.optim import sgd

    opt = error_feedback_optimizer(sgd(0.1), bits=2)
    params = {"w": jnp.zeros(64)}
    state = opt.init(params)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,)) * 0.3}
    for _ in range(3):
        prev_res = state["residual"]["w"]
        params, state = opt.update(g, state, params)
        # reconstruct q from the residual identity
        q = g["w"] + prev_res - state["residual"]["w"]
        np.testing.assert_allclose(
            np.asarray(q + state["residual"]["w"]),
            np.asarray(g["w"] + prev_res), atol=1e-6)


def test_error_feedback_tracks_signal_at_1bit():
    """Over T steps the EF-compressed cumulative update approaches the true
    cumulative gradient (plain 1-bit quantization has persistent bias)."""
    from repro.core.compression import error_feedback_optimizer
    from repro.optim import sgd

    g = {"w": jnp.asarray([0.3, -0.02, 0.11, 0.9])}  # very non-uniform
    t = 12

    def run(opt):
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        for _ in range(t):
            params, state = opt.update(g, state, params)
        return np.asarray(params["w"])

    exact = -0.1 * t * np.asarray(g["w"])
    ef = run(error_feedback_optimizer(sgd(0.1), bits=1))
    err_ef = np.abs(ef - exact).max()

    plain_q = C.encode_decode_tree(g, 1)
    plain = -0.1 * t * np.asarray(plain_q["w"])
    err_plain = np.abs(plain - exact).max()
    assert err_ef < err_plain


# --------------------------------------------------------------------------
# Transformer-scale payload accounting (int64 / Python-int arithmetic)
# --------------------------------------------------------------------------


def test_payload_bits_transformer_scale_exact_int():
    """A 10^8-param tree at 32 bits is ~3.2e9 — beyond int32.  payload_bits
    must return the exact Python int (no 32-bit dtype round-trip)."""
    big = {"emb": np.zeros((100_000_000,), np.float32)}
    bits = C.payload_bits(big)
    assert isinstance(bits, int)
    assert bits == 3_200_000_000
    assert bits > np.iinfo(np.int32).max


def test_budget_accounting_survives_transformer_scale():
    """Failing before: the raw Python int entered jnp math, which
    canonicalizes host ints to int32 (x64 off) and raised OverflowError —
    silently impossible to budget a transformer-class payload.  The float
    coercion keeps §IV airtime budgets finite and correct."""
    payload = 100_000_000 * 32
    budgets = jnp.asarray([1e6, 32e9, 1e12])
    ratios = np.asarray(q.compression_ratio(payload, budgets))
    np.testing.assert_allclose(ratios[0], 3.2e3, rtol=1e-6)
    assert ratios[1] == 1.0  # c >= I: no compression needed
    bits = np.asarray(q.adaptive_bits(payload, budgets))
    np.testing.assert_array_equal(bits, [1, 32, 32])


def test_budget_accounting_lenet_scale_bit_identical():
    """The float coercion must not perturb the historical in-range path:
    LeNet's 8,531,520-bit payload is exactly f32-representable, so ratios
    and bits match the pre-fix int arithmetic bit for bit."""
    payload = 266_610 * 32
    budgets = jnp.asarray([1.0e5, 8.0e5, 4.0e6, 1.0e9])
    ratios = np.asarray(q.compression_ratio(payload, budgets))
    np.testing.assert_array_equal(
        ratios, np.maximum(np.float32(payload) / budgets, 1.0))
    bits = np.asarray(q.adaptive_bits(payload, budgets))
    np.testing.assert_array_equal(
        bits, np.clip(np.floor(32.0 / ratios), 1, 32).astype(np.int32))


# --------------------------------------------------------------------------
# Top-k sparsification stage (composable before DoReFa)
# --------------------------------------------------------------------------


def test_topk_index_bits():
    assert C.topk_index_bits(2) == 1
    assert C.topk_index_bits(1024) == 10
    assert C.topk_index_bits(1025) == 11
    assert C.topk_index_bits(266_610) == 19
    with pytest.raises(ValueError):
        C.topk_index_bits(0)


def test_topk_plan_budget_split():
    """kept spends the budget at the 1-bit floor (2 + idx bits/coord, fp32
    scale off the top), capped by the topk fraction; leftover per-coord
    budget becomes the DoReFa width."""
    p = 1024  # idx = 10 bits
    kept, bits = (np.asarray(v) for v in C.topk_plan(
        p, jnp.asarray([12.0 * 50 + 32.0]), topk=1.0))
    assert kept[0] == 50          # 50 coords affordable at the 1-bit floor
    assert bits[0] == 1
    # generous budget, tight cap: kept clamps to ceil(topk * P) and the
    # surplus budget widens the code
    kept, bits = (np.asarray(v) for v in C.topk_plan(
        p, jnp.asarray([1e6]), topk=0.01))
    assert kept[0] == int(np.ceil(0.01 * p))
    assert bits[0] == 32          # per-coord budget saturates the clamp
    # starvation edge: even a zero budget keeps one coordinate at 1 bit
    kept, bits = (np.asarray(v) for v in C.topk_plan(
        p, jnp.asarray([0.0]), topk=0.5))
    assert kept[0] == 1 and bits[0] == 1


def test_topk_mask_matches_numpy_oracle(rng):
    flat = jnp.asarray(rng.standard_normal((4, 37)).astype(np.float32))
    kept = jnp.asarray([0, 1, 5, 37], jnp.int32)
    mask = np.asarray(C.topk_mask(flat, kept))
    f = np.asarray(flat)
    for i, k in enumerate([0, 1, 5, 37]):
        keep = np.argsort(-np.abs(f[i]), kind="stable")[:k]
        want = np.zeros(37, np.float32)
        want[keep] = 1.0
        np.testing.assert_array_equal(mask[i], want)
    # k=0 row is all-zero, k=N row is identity
    assert mask[0].sum() == 0
    np.testing.assert_array_equal(mask[3], np.ones(37, np.float32))


def test_sparse_payload_accounting():
    """S_k = k * (b + 1 + idx) + 32, and the honest ratio I / S_k."""
    p = 266_610
    payload = p * 32
    kept = np.asarray([100, p])
    bits = np.asarray([4, 32])
    s = C.sparse_payload_bits(kept, bits, p)
    idx = C.topk_index_bits(p)
    np.testing.assert_array_equal(
        s, [100 * (4 + 1 + idx) + 32, p * (32 + 1 + idx) + 32])
    r = C.sparse_compression_ratio(payload, kept, bits, p)
    np.testing.assert_allclose(r[0], payload / s[0])
    assert r[1] == 1.0   # dense-at-33-bits costs more than raw: clamps to 1
