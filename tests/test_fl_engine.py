"""Engine-equality grid: the batched FL round engine vs the legacy oracle.

The batched engine (``FLConfig.fl_engine = "batched"``) must reproduce the
legacy per-device loop across uplink x compression x policy: identical
device groups, bit-widths, budgets/rates and compression ratios (the driver
computes those once, and the engine's traced adaptive bits must equal the
legacy host ints), with accuracy trajectories and final parameters equal to
f32 tolerance.  Includes the T*K > M empty-tail-round case and the Pallas
aggregation path pinned against the XLA einsum.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like

M = 12


@pytest.fixture(scope="module")
def world():
    ds = make_mnist_like(num_samples=800, seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.y_train, M, seed=0)
    return ds, cell, shards


@pytest.fixture(scope="module")
def tiny_world():
    """4-device cell so a 3-round, K=2 horizon exhausts the device set."""
    ds = make_mnist_like(num_samples=400, seed=0)
    cell = channel.CellConfig(num_devices=4)
    shards = dirichlet_partition(ds.y_train, 4, seed=0)
    return ds, cell, shards


def _run(world, engine, *, uplink="noma", compression="adaptive",
         scheduler="lazy-gwmin", use_pallas=False, m=M, group_size=3,
         rounds=3):
    ds, cell, shards = world
    cfg = FLConfig(num_devices=m, group_size=group_size, num_rounds=rounds,
                   scheduler=scheduler, power_mode="max",
                   compression=compression, fl_engine=engine,
                   use_pallas=use_pallas, seed=0)
    return fl.run_federated_learning(ds, shards, cell, cfg, uplink=uplink)


def _assert_equal_runs(a, b, *, acc_atol=0.02, param_mean_atol=1e-6,
                       param_max_atol=2e-2):
    assert [l.devices for l in a.logs] == [l.devices for l in b.logs]
    for la, lb in zip(a.logs, b.logs):
        np.testing.assert_array_equal(la.bits, lb.bits)
        np.testing.assert_array_equal(la.rates, lb.rates)
        np.testing.assert_array_equal(la.compression_ratios,
                                      lb.compression_ratios)
    np.testing.assert_array_equal(a.times(), b.times())
    np.testing.assert_allclose(a.accuracies(), b.accuracies(), atol=acc_atol)
    # Per-element deltas between the engines are ulp-level, but a delta
    # element landing exactly on a DoReFa round() boundary flips by one
    # full quantization step (scale / (2^b - 1)) — a rare, isolated,
    # legitimate divergence.  Compare distributions instead of elementwise:
    # a systematic engine bug moves the mean, a boundary flip does not.
    for x, y in zip(jax.tree_util.tree_leaves(a.final_params),
                    jax.tree_util.tree_leaves(b.final_params)):
        d = np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))
        assert d.mean() < param_mean_atol, f"mean param drift {d.mean()}"
        assert d.max() < param_max_atol, f"max param drift {d.max()}"


# lazy-gwmin: the paper's precomputed MWIS policy; update-aware: online,
# needs_norms=True, so the engines' update-norm signals steer selection live
@pytest.mark.parametrize("scheduler", ["lazy-gwmin", "update-aware"])
@pytest.mark.parametrize("compression", ["adaptive", "none"])
@pytest.mark.parametrize("uplink", ["noma", "tdma"])
def test_engine_equality_grid(world, uplink, compression, scheduler):
    legacy = _run(world, "legacy", uplink=uplink, compression=compression,
                  scheduler=scheduler)
    batched = _run(world, "batched", uplink=uplink, compression=compression,
                   scheduler=scheduler)
    _assert_equal_runs(legacy, batched)


@pytest.mark.parametrize("uplink", ["noma", "tdma"])
def test_engine_equality_empty_tail_rounds(tiny_world, uplink):
    """T*K > M round-robin schedules end in empty groups; both engines must
    log them identically (no training, wall clock still advances)."""
    legacy = _run(tiny_world, "legacy", uplink=uplink,
                  scheduler="round-robin", m=4, group_size=2, rounds=3)
    batched = _run(tiny_world, "batched", uplink=uplink,
                   scheduler="round-robin", m=4, group_size=2, rounds=3)
    assert batched.logs[-1].devices == ()
    assert batched.logs[-1].bits.size == 0
    _assert_equal_runs(legacy, batched)


@pytest.mark.parametrize("compression", ["adaptive", "none"])
def test_pallas_aggregation_matches_xla(tiny_world, compression):
    """use_pallas routes aggregation through the fused dequant+aggregate
    kernel; results must match the default XLA einsum path to f32
    tolerance (and bits/schedules exactly)."""
    xla = _run(tiny_world, "batched", compression=compression, m=4,
               group_size=2, rounds=3, scheduler="round-robin")
    pallas = _run(tiny_world, "batched", compression=compression, m=4,
                  group_size=2, rounds=3, scheduler="round-robin",
                  use_pallas=True)
    # both paths derive identical codes (shared quantize_codes_batched), so
    # only reduction order differs — no rounding-flip allowance needed
    _assert_equal_runs(xla, pallas, param_max_atol=1e-4)


def test_pallas_aggregate_leaf_b32_passthrough():
    """A b >= 32 client must pass through full precision on the Pallas
    path too — under the paper-exact fixed [-1, 1] range its codes would
    otherwise clip any |delta| > 1 (regression: the kernel path used to
    quantize every client unconditionally)."""
    import jax.numpy as jnp

    from repro.core import fl_engine

    leaf = jnp.asarray([[1.5, -2.0, 0.3], [0.5, 0.25, -0.125]], jnp.float32)
    bits = jnp.asarray([32, 2], jnp.int32)
    w = jnp.asarray([0.5, 0.5], jnp.float32)
    out = fl_engine._pallas_aggregate_leaf(
        leaf, bits, w, compress=True, paper_exact=True)
    a = 3.0  # 2^2 - 1 levels for the quantized client
    q1 = np.round(a * np.clip(np.asarray(leaf[1]), -1.0, 1.0)) / a
    want = 0.5 * np.asarray(leaf[0]) + 0.5 * q1
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6, atol=1e-7)


def test_batched_engine_deterministic(tiny_world):
    a = _run(tiny_world, "batched", m=4, group_size=2, rounds=3,
             scheduler="age-fair")
    b = _run(tiny_world, "batched", m=4, group_size=2, rounds=3,
             scheduler="age-fair")
    assert [l.devices for l in a.logs] == [l.devices for l in b.logs]
    np.testing.assert_array_equal(a.accuracies(), b.accuracies())


def test_unknown_engine_rejected_at_config_time():
    with pytest.raises(ValueError, match="unknown fl_engine"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 fl_engine="warp-drive")


def test_evalbank_full_eval_matches_legacy(tiny_world):
    """engine.evaluate at eval_sample = 1.0 routes through the EvalBank but
    must equal the legacy driver's lenet.accuracy over the raw test arrays
    bit for bit (same arrays, same jitted computation)."""
    import jax.numpy as jnp

    from repro.core import fl_engine
    from repro.models import lenet
    from repro.models.params import init_params

    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   fl_engine="batched", seed=0)
    engine = fl_engine.BatchedRoundEngine(ds, shards, cfg, payload_bits=32)
    params = init_params(lenet.schema(), jax.random.PRNGKey(0))
    want = float(jax.jit(lenet.accuracy)(
        params, jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)))
    for t in range(cfg.num_rounds):
        assert engine.evaluate(params, t) == want


def test_evalbank_sampled_eval_deterministic_and_plan_shaped(tiny_world):
    """eval_sample < 1: per-round precomputed sample plans — deterministic
    across engine rebuilds (seeded), ceil(frac * N) rows each, rounds
    differ, and the run's schedules/bits are unaffected (eval never feeds
    back into training)."""
    import numpy as np

    from repro.core import fl_engine
    from repro.data import eval_sample_plan

    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   fl_engine="batched", eval_sample=0.5, seed=0)
    e1 = fl_engine.BatchedRoundEngine(ds, shards, cfg, payload_bits=32)
    e2 = fl_engine.BatchedRoundEngine(ds, shards, cfg, payload_bits=32)
    n_test = len(ds.y_test)
    assert e1._eval_idx.shape == (3, int(np.ceil(0.5 * n_test)))
    np.testing.assert_array_equal(e1._eval_idx, e2._eval_idx)
    assert not np.array_equal(e1._eval_idx[0], e1._eval_idx[1])
    for t in range(3):  # without-replacement draw within each round
        assert len(set(e1._eval_idx[t].tolist())) == e1._eval_idx.shape[1]
    # the plan helper is the single owner both drivers share
    np.testing.assert_array_equal(
        e1._eval_idx, eval_sample_plan(n_test, 0.5, 3, 0))
    # training itself is untouched by sampled eval
    full = _run(tiny_world, "batched", m=4, group_size=2, rounds=3,
                scheduler="round-robin")
    ds2, cell2, shards2 = tiny_world
    cfg_s = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                     scheduler="round-robin", power_mode="max",
                     fl_engine="batched", eval_sample=0.5, seed=0)
    sampled = fl.run_federated_learning(ds2, shards2, cell2, cfg_s)
    assert ([l.devices for l in full.logs]
            == [l.devices for l in sampled.logs])
    for lf, ls in zip(full.logs, sampled.logs):
        np.testing.assert_array_equal(lf.bits, ls.bits)


def test_eval_sample_rejected_for_legacy_engine():
    with pytest.raises(ValueError, match="eval_sample < 1 requires"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 fl_engine="legacy", eval_sample=0.5)
    with pytest.raises(ValueError, match="eval_sample must be in"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2, eval_sample=0.0)


# --------------------------------------------------------------------------
# Model-agnostic payload path: registry models through the same engines
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def token_world():
    """Token-shard world: a tiny registry transformer's next-token corpus,
    Dirichlet-partitioned by the rows' pseudo-class like the image path."""
    from repro.data.tokens import make_token_dataset

    ds = make_token_dataset(vocab_size=64, num_samples=400, seq_len=8,
                            seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.class_train, M, seed=0)
    return ds, cell, shards


def _run_model(world, engine, *, model, uplink="noma",
               compression="adaptive", topk=1.0, client_bank="padded",
               horizon="per-round", rounds=3):
    ds, cell, shards = world
    cfg = FLConfig(num_devices=M, group_size=3, num_rounds=rounds,
                   learning_rate=0.05, batch_size=8,
                   scheduler="lazy-gwmin", power_mode="max",
                   compression=compression, fl_engine=engine,
                   model=model, topk=topk, client_bank=client_bank,
                   horizon=horizon, seed=0)
    return fl.run_federated_learning(ds, shards, cell, cfg, uplink=uplink)


@pytest.mark.parametrize("uplink", ["noma", "tdma"])
def test_engine_equality_grid_transformer(token_world, uplink):
    """The engine x model grid: batched vs legacy on a tiny registry
    transformer (not just LeNet) — identical schedules/bits/rates/ratios/
    times, f32-tolerance accuracies, exactly as the LeNet grid pins."""
    legacy = _run_model(token_world, "legacy", model="tiny-transformer",
                        uplink=uplink)
    batched = _run_model(token_world, "batched", model="tiny-transformer",
                         uplink=uplink)
    _assert_equal_runs(legacy, batched)


def test_bucketed_bank_equality(token_world, world):
    """client_bank='bucketed' gathers element-equal rows through per-bucket
    banks, so the whole run matches the padded bank bit for bit — on both
    image and token shards."""
    for w, model in ((world, "lenet"), (token_world, "tiny-transformer")):
        padded = _run_model(w, "batched", model=model)
        bucketed = _run_model(w, "batched", model=model,
                              client_bank="bucketed")
        assert ([l.devices for l in padded.logs]
                == [l.devices for l in bucketed.logs])
        np.testing.assert_array_equal(padded.accuracies(),
                                      bucketed.accuracies())
        for lp, lb in zip(padded.logs, bucketed.logs):
            np.testing.assert_array_equal(lp.bits, lb.bits)
        for x, y in zip(jax.tree_util.tree_leaves(padded.final_params),
                        jax.tree_util.tree_leaves(bucketed.final_params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bucketed_bank_rejected_outside_batched_per_round():
    with pytest.raises(ValueError, match="bucketed"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 fl_engine="legacy", client_bank="bucketed")
    with pytest.raises(ValueError, match="bucketed"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 fl_engine="batched", horizon="scan",
                 client_bank="bucketed")


def test_topk_rejected_for_legacy_engine():
    with pytest.raises(ValueError, match="topk"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 fl_engine="legacy", topk=0.1)
    with pytest.raises(ValueError, match="topk must be in"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2, topk=0.0)
    with pytest.raises(ValueError, match="compression='adaptive'"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 fl_engine="batched", compression="none", topk=0.1)


# --------------------------------------------------------------------------
# Top-k ∘ DoReFa composition vs a numpy oracle
# --------------------------------------------------------------------------


def _sparse_oracle(deltas_np, budgets_np, agg_w_np, *, payload, topk):
    """Numpy re-derivation of _sparse_quantize_aggregate: whole-payload
    top-k mask (stable magnitude order, ties by position), per-row DoReFa
    on the survivors, b >= 32 passthrough, weighted sum."""
    from repro.core import compression as C

    k = deltas_np.shape[0]
    p = payload // 32
    kept, bits = (np.asarray(v) for v in C.topk_plan(
        p, budgets_np, topk=topk))
    out = np.zeros(deltas_np.shape[1], np.float64)
    for i in range(k):
        row = deltas_np[i].astype(np.float32)
        order = np.argsort(-np.abs(row), kind="stable")
        mask = np.zeros_like(row)
        mask[order[:kept[i]]] = 1.0
        masked = row * mask
        if bits[i] >= 32:
            out += agg_w_np[i] * masked.astype(np.float64)
            continue
        a = np.float32(2.0 ** bits[i] - 1.0)
        scale = np.float32(max(np.abs(masked).max(), 1e-12))
        codes = np.round(a * np.clip(masked / scale, -1.0, 1.0))
        out += agg_w_np[i] * (codes.astype(np.float64) / a) * scale
    return out, kept, bits


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sparse_quantize_aggregate_matches_numpy_oracle(rng, use_pallas):
    from repro.core import fl_engine

    k, p = 3, 64
    deltas = {"w": rng.standard_normal((k, 8, 4)).astype(np.float32),
              "b": rng.standard_normal((k, 32)).astype(np.float32)}
    budgets = np.asarray([300.0, 700.0, 1e6])   # 1-bit floor .. b=32
    agg_w = np.asarray([0.2, 0.3, 0.5], np.float32)
    update, kept, bits = fl_engine._sparse_quantize_aggregate(
        {kk: jnp.asarray(v) for kk, v in deltas.items()},
        jnp.asarray(budgets), jnp.asarray(agg_w),
        payload=p * 32, topk=0.8, paper_exact=False,
        use_pallas=use_pallas)
    flat = np.concatenate(
        [deltas["b"].reshape(k, -1), deltas["w"].reshape(k, -1)], axis=1)
    want, kept_w, bits_w = _sparse_oracle(
        flat, budgets, agg_w, payload=p * 32, topk=0.8)
    np.testing.assert_array_equal(np.asarray(kept), kept_w)
    np.testing.assert_array_equal(np.asarray(bits), bits_w)
    got = np.concatenate([
        np.asarray(update["b"]).reshape(-1),
        np.asarray(update["w"]).reshape(-1)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # the budget split must actually sparsify the starved client and
    # full-precision the rich one
    assert kept_w[0] < p and bits_w[2] == 32


def test_sparse_stage_edges(rng):
    """k-edges through the composed stage: a budget so rich every client
    keeps all of a tiny payload at b=32 (masking is the identity — the
    aggregate equals the plain weighted sum), and a starved budget that
    keeps exactly one coordinate per client."""
    from repro.core import compression as C
    from repro.core import fl_engine

    k, p = 2, 16
    deltas = {"w": rng.standard_normal((k, p)).astype(np.float32)}
    agg_w = np.asarray([0.4, 0.6], np.float32)
    rich, kept, bits = fl_engine._sparse_quantize_aggregate(
        {"w": jnp.asarray(deltas["w"])},
        jnp.asarray([1e9, 1e9]), jnp.asarray(agg_w),
        payload=p * 32, topk=1.0, paper_exact=False, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(kept), [p, p])
    np.testing.assert_array_equal(np.asarray(bits), [32, 32])
    np.testing.assert_allclose(
        np.asarray(rich["w"]),
        (agg_w[:, None] * deltas["w"]).sum(0), rtol=1e-6, atol=1e-7)
    _, kept0, bits0 = fl_engine._sparse_quantize_aggregate(
        {"w": jnp.asarray(deltas["w"])},
        jnp.asarray([0.0, 0.0]), jnp.asarray(agg_w),
        payload=p * 32, topk=0.5, paper_exact=False, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(kept0), [1, 1])
    np.testing.assert_array_equal(np.asarray(bits0), [1, 1])


def test_topk_run_logs_honest_sparse_ratios(token_world):
    """A topk < 1 run's logged compression ratios are the sparse on-air
    ratios I / S_k, strictly larger than the dense DoReFa ratios the same
    budgets produce at the same bits."""
    from repro.core import compression as C

    res = _run_model(token_world, "batched", model="tiny-transformer",
                     topk=0.05)
    for log in res.logs:
        if log.bits.size == 0:
            continue
        assert np.all(log.compression_ratios >= 1.0)
        # honest accounting: ratios come from the (kept, bits) pair, so a
        # 32-bit client can still report r > 1 when it kept few coords
        assert np.all(np.isfinite(log.compression_ratios))
