"""flcheck — trace-safety & determinism static analysis for this repo.

``python -m tools.flcheck src tests benchmarks examples`` runs the pass;
``python -m tools.flcheck --selftest`` checks the rule corpus.  The checker
half (:mod:`tools.flcheck.checker`) is stdlib-only; the runtime half
(:mod:`tools.flcheck.sanitizers` — compile-count guard, NaN sanitizer)
imports JAX and is pulled in only by the code that uses it.
"""
from tools.flcheck.checker import (  # noqa: F401
    RULES, Diagnostic, check_file, check_paths, find_errors_module,
    pinned_fragments,
)
