"""Jit'd public wrappers around the Pallas kernels (with jnp fallback).

Shapes are massaged here: flatten -> pad to (rows, 128) with rows a multiple
of BLOCK_ROWS -> kernel -> unpad. ``use_pallas`` selects the Pallas path
(interpret-mode on CPU, Mosaic on TPU); the default jnp path is used inside
large jitted train steps where XLA fusion is already optimal and a
Python-interpreted kernel would be pure overhead on this CPU container.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import dorefa
from repro.kernels.aggregate import weighted_aggregate_pallas
from repro.kernels.dorefa import BLOCK_ROWS, LANE
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.sic_rates import sic_weighted_rates_pallas

_TILE = BLOCK_ROWS * LANE


def _to_tiles(flat: jax.Array):
    n = flat.shape[0]
    pad = (-n) % _TILE
    x = jnp.pad(flat, (0, pad))
    return x.reshape(-1, LANE), n


def _from_tiles(x2d: jax.Array, n: int):
    return x2d.reshape(-1)[:n]


def max_abs_scale(x: jax.Array) -> jax.Array:
    """Two-pass scheme, pass 1: per-tensor max-abs scale (XLA reduction)."""
    return jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12)


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def quantize_pack(flat: jax.Array, bits: int, *, use_pallas: bool = False):
    """Flat vector -> (codes int32 (padded 2D), scale). Static bits."""
    scale = max_abs_scale(flat)
    x2d, _ = _to_tiles(flat.astype(jnp.float32))
    if use_pallas:
        codes = dorefa.quantize_codes_pallas(x2d, scale, bits)
    else:
        codes = ref.quantize_codes_ref(x2d, bits, scale)
    return codes, scale


@functools.partial(jax.jit, static_argnames=("bits", "size", "use_pallas"))
def unpack_dequantize(
    codes2d: jax.Array, scale: jax.Array, bits: int, size: int,
    *, use_pallas: bool = False,
):
    if use_pallas:
        x2d = dorefa.dequantize_codes_pallas(codes2d, scale, bits)
    else:
        x2d = ref.dequantize_codes_ref(codes2d, bits, scale)
    return _from_tiles(x2d, size)


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def quantize_dequantize(x: jax.Array, bits: int, *, use_pallas: bool = False):
    """Fused uplink simulation for one tensor (any shape)."""
    flat = x.reshape(-1)
    scale = max_abs_scale(flat)
    x2d, n = _to_tiles(flat)
    if use_pallas:
        y2d = dorefa.quantize_dequantize_pallas(x2d, scale, bits)
    else:
        y2d = ref.quantize_dequantize_ref(x2d, bits, scale)
    return _from_tiles(y2d, n).reshape(x.shape).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def weighted_aggregate(
    codes: jax.Array,    # (K, ...) int32 — stacked client payloads, any shape
    scales: jax.Array,   # (K,)
    weights: jax.Array,  # (K,)
    bits: int,
    *,
    use_pallas: bool = False,
):
    if use_pallas:
        return weighted_aggregate_pallas(codes, scales, weights, bits)
    k = codes.shape[0]
    return ref.weighted_aggregate_ref(
        codes.reshape(k, -1), scales, weights, bits
    ).reshape(codes.shape[1:])


@functools.partial(jax.jit, static_argnames=("noise_power", "use_pallas"))
def sic_weighted_rates(
    powers_vk: jax.Array,
    gains_vk: jax.Array,
    weights_vk: jax.Array,
    noise_power: float,
    *,
    use_pallas: bool = False,
):
    """Batched NOMA SIC group scoring: (V, K) rows -> (V,) weighted rates.

    The scheduler-side (control-plane) engine is ``repro.core.rates``; this
    is the accelerator mirror for scoring huge candidate batches on device
    (use_pallas selects the comparison-matrix Mosaic kernel, interpret mode
    on CPU; the default path is the shared jnp engine in
    ``repro.core.rates_jax``, which also powers the device-resident MWIS
    greedy in ``repro.core.scheduling`` at float64).
    """
    if use_pallas:
        return sic_weighted_rates_pallas(
            powers_vk, gains_vk, weights_vk, noise_power
        )
    return ref.sic_weighted_rates_ref(powers_vk, gains_vk, weights_vk, noise_power)


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_s"))
def flash_decode(q, k, v, valid_len, *, use_pallas: bool = False,
                 block_s: int = 256):
    """One-token GQA decode attention over a cache (serving hot loop).

    q: (B, Hkv, G, D); k, v: (B, S, Hkv, D); valid_len: scalar int32.
    use_pallas selects the Mosaic flash-decode kernel (interpret on CPU).
    """
    if use_pallas:
        return flash_decode_pallas(q, k, v, jnp.asarray(valid_len),
                                   block_s=block_s)
    return ref.flash_decode_ref(q, k, v, valid_len)
