"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels are validated against
these with assert_allclose across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_codes_ref(x: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    """DoReFa integer codes: round(a * clip(x/scale, -1, 1)), a = 2^b - 1."""
    a = float(2 ** int(bits) - 1)
    xn = jnp.clip(x.astype(jnp.float32) / scale, -1.0, 1.0)
    return jnp.round(a * xn).astype(jnp.int32)


def dequantize_codes_ref(codes: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    a = float(2 ** int(bits) - 1)
    return codes.astype(jnp.float32) / a * scale


def quantize_dequantize_ref(x: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    """Fused q->dq (the uplink simulation used inside train steps)."""
    return dequantize_codes_ref(quantize_codes_ref(x, bits, scale), bits, scale).astype(
        x.dtype
    )


def weighted_aggregate_ref(
    codes: jnp.ndarray,    # (K, N) int32
    scales: jnp.ndarray,   # (K,)
    weights: jnp.ndarray,  # (K,)
    bits: int,
) -> jnp.ndarray:
    """Server-side fused dequant + weighted sum:  sum_k w_k dq(codes_k)."""
    a = float(2 ** int(bits) - 1)
    deq = codes.astype(jnp.float32) / a * scales[:, None]
    return jnp.sum(weights[:, None] * deq, axis=0)


def flash_decode_ref(q, k, v, valid_len):
    """One-token GQA decode oracle. q: (B,Hkv,G,D); k,v: (B,S,Hkv,D)."""
    d = q.shape[-1]
    s = jnp.einsum(
        "bhgd,bshd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(d)
    pos = jnp.arange(k.shape[1])
    s = jnp.where(pos[None, None, None, :] < valid_len, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)


def sic_weighted_rates_ref(powers_vk, gains_vk, weights_vk, noise_power):
    """Batched SIC weighted sum rate oracle: (V, K) -> (V,).

    Sort + suffix-sum formulation (mirrors repro.core.rates): decode in
    descending receive-power order, each sorted position's interference is
    the suffix sum of receive powers decoded after it.  jnp.argsort is
    stable, so ties break by lower input index — same order as the numpy
    engine and the Pallas comparison-matrix kernel.
    """
    rx = (powers_vk * gains_vk * gains_vk).astype(jnp.float32)
    order = jnp.argsort(-rx, axis=-1)
    rx_s = jnp.take_along_axis(rx, order, axis=-1)
    w_s = jnp.take_along_axis(weights_vk.astype(jnp.float32), order, axis=-1)
    suffix = jnp.cumsum(rx_s[..., ::-1], axis=-1)[..., ::-1]
    tail = suffix - rx_s
    return jnp.sum(w_s * jnp.log2(1.0 + rx_s / (tail + noise_power)), axis=-1)
