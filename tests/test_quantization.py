"""DoReFa quantization (paper Eq. 7) properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.core import quantization as q


def test_levels():
    assert float(q.dorefa_levels(1)) == 1.0
    assert float(q.dorefa_levels(8)) == 255.0


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_quantize_error_bound(bits, seed):
    """|x - q(x)| <= scale / (2 * (2^b - 1)) for x in [-scale, scale]."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 0.5
    y = q.quantize(x, bits)
    scale = float(jnp.max(jnp.abs(x)))
    bound = scale / (2 * (2**bits - 1)) + 1e-6
    assert float(jnp.max(jnp.abs(x - y))) <= bound


def test_quantize_paper_exact_matches_eq7():
    """With scale=1 the codec is exactly (1/a) round(a*pi)."""
    x = jnp.asarray([-1.0, -0.51, 0.0, 0.26, 0.74, 1.0])
    for b in (1, 2, 3):
        a = 2**b - 1
        np.testing.assert_allclose(
            np.asarray(q.quantize(x, b, scale=1.0)),
            np.round(a * np.asarray(x)) / a,
            atol=1e-7,
        )


def test_quantize_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    y = q.quantize(x, 5)
    # quantizing an already-quantized tensor with the same scale is identity
    z = q.quantize(y, 5, scale=float(jnp.max(jnp.abs(x))))
    np.testing.assert_allclose(np.asarray(y), np.asarray(z), atol=1e-6)


def test_bits_32_passthrough():
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    np.testing.assert_array_equal(np.asarray(q.quantize(x, 32)), np.asarray(x))


def test_adaptive_bits_formula():
    # r = max(I/c, 1); b = floor(32/r) clamped to [1, 32]  (paper §II-B)
    assert int(q.adaptive_bits(3200.0, 1600.0)) == 16
    assert int(q.adaptive_bits(3200.0, 3200.0)) == 32
    assert int(q.adaptive_bits(3200.0, 1e12)) == 32
    assert int(q.adaptive_bits(3200.0, 10.0)) == 1  # clamp at 1 bit
    assert int(q.adaptive_bits(3200.0, 800.0)) == 8


@settings(max_examples=30, deadline=None)
@given(st.floats(1e3, 1e9), st.floats(1.0, 1e9))
def test_adaptive_bits_monotone_in_budget(payload, budget):
    b1 = int(q.adaptive_bits(payload, budget))
    b2 = int(q.adaptive_bits(payload, budget * 2))
    assert 1 <= b1 <= 32 and b1 <= b2


def test_quantize_tree_structure_preserved():
    tree = {"a": jnp.ones((4, 4)), "b": [jnp.zeros(3), jnp.full((2,), 0.3)]}
    out = q.quantize_tree(tree, 4)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)


@pytest.mark.parametrize("paper_exact", [False, True])
def test_quantize_tree_batched_bits_matches_per_client(paper_exact):
    """(K,) bits mode == calling quantize on each client row with its own
    bits, bit for bit (incl. the b >= 32 passthrough and per-row scales)."""
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    tree = {
        "w": jax.random.normal(k1, (4, 7, 5)) * 0.7,
        "b": jax.random.normal(k2, (4, 3)) * 2.0,
    }
    bits = jnp.asarray([1, 4, 8, 32], jnp.int32)
    out = q.quantize_tree(tree, bits, paper_exact=paper_exact)
    for i in range(4):
        row = {"w": tree["w"][i], "b": tree["b"][i]}
        want = q.quantize_tree(row, int(bits[i]), paper_exact=paper_exact)
        np.testing.assert_array_equal(np.asarray(out["w"][i]),
                                      np.asarray(want["w"]))
        np.testing.assert_array_equal(np.asarray(out["b"][i]),
                                      np.asarray(want["b"]))


def test_quantize_batched_traced_bits_under_jit():
    """The (K,) bits vector may be traced (the batched FL engine passes the
    adaptive bits computed inside the same jit)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 64))

    @jax.jit
    def f(x, budgets):
        bits = q.adaptive_bits(64 * 32, budgets)
        return q.quantize_tree({"g": x}, bits)["g"], bits

    got, bits = f(x, jnp.asarray([100.0, 700.0, 1e9]))
    want = jnp.stack([q.quantize(x[i], int(bits[i])) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_error_decreases_with_bits():
    x = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    errs = [float(q.quantization_error(x, b)) for b in (1, 2, 4, 8, 16)]
    assert all(e1 > e2 for e1, e2 in zip(errs, errs[1:]))
