"""Over-the-air vs digital uplink: round wall-clock and accuracy.

One round of digital NOMA FL costs K decode-and-dequant payload passes plus
the weighted aggregation; the analog OTA uplink (``repro.core.ota``)
replaces all of it with a single noisy superposition the PS reads off the
air.  This bench runs the same scanned horizon (batched engine, identical
schedule/world/seed) under three aggregation back ends:

  * ``noma``       — digital decode-and-average, uncompressed payloads
    (``compression="none"`` so both uplinks move the same raw update
    vector and the delta is purely the aggregation path);
  * ``ota``        — analog superposition through the XLA einsum reducer;
  * ``ota_pallas`` — the same superposition through the fused
    scale+superpose+denoise Pallas kernel
    (:func:`repro.kernels.aggregate.ota_aggregate_pallas`, interpret mode
    on CPU — see BENCH_payload.json for why XLA wins on this host).

Each record carries the matched-SNR final accuracy next to the timing: the
OTA rows run at a receiver noise floor scaled to the §IV cell physics
(``ota_noise = NOISE_STD``), so the accuracy column shows what the analog
sum's noise actually costs the learning curve, and the noiseless
``ota_noise = 0`` row pins the exact-aggregate equivalence.

``benchmarks/run.py`` persists the records to ``BENCH_ota.json``
(``BENCH_ota_fast.json`` under --fast/--smoke) and gates ``horizon_s``
under ``--check-regression``.
"""
from __future__ import annotations

import time

from benchmarks.common import build_world, emit
from repro.config import FLConfig
from repro.core import fl

NOISE_STD = 1e-9
# Receiver noise std for the noisy OTA rows.  The §IV cell (pmax = 10 mW,
# gains ~1e-6) puts the channel-inverted update referral near 1e-7-1e-8 per
# unit update norm, so 1e-9 is a high-but-not-clean SNR: the learning curve
# moves without collapsing, which is what a cost-of-noise column should show.

VARIANTS = (
    # (record name, uplink, ota_noise, use_pallas)
    ("noma", "noma", 0.0, False),
    ("ota_noiseless", "ota", 0.0, False),
    ("ota", "ota", NOISE_STD, False),
    ("ota_pallas", "ota", NOISE_STD, True),
)


def _horizon_seconds(world, cfg, *, passes: int = 2) -> "tuple[float, float]":
    """Best-of wall seconds for one full scanned horizon + final accuracy.

    One warm-up run pays the trace/compile; the timed passes rerun the
    whole driver (host plan + device scan), which is the unit a sweep
    script actually dispatches.
    """
    res = fl.run_horizon_scanned(world.dataset, world.shards, world.cell, cfg)
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        res = fl.run_horizon_scanned(
            world.dataset, world.shards, world.cell, cfg
        )
        best = min(best, time.perf_counter() - t0)
    return best, float(res.accuracies()[-1])


def main(fast: bool = False) -> dict:
    m = 24 if fast else 60
    rounds = 4 if fast else 12
    world = build_world(num_devices=m, num_samples=1500 if fast else 4000)
    records = []
    for name, uplink, noise, pallas in VARIANTS:
        cfg = FLConfig(
            num_devices=m, group_size=3, num_rounds=rounds,
            scheduler="lazy-gwmin", power_mode="max", compression="none",
            fl_engine="batched", horizon="scan", use_pallas=pallas,
            uplink=uplink, ota_noise=noise, seed=0,
        )
        seconds, acc = _horizon_seconds(world, cfg)
        records.append({
            "variant": name, "uplink": uplink, "ota_noise": noise,
            "pallas": pallas, "m": m, "k": 3, "rounds": rounds,
            "horizon_s": seconds,
            # rounded: this column is part of the --check-regression record
            # identity, and baseline matching should survive ulp-level
            # accuracy drift across hosts
            "final_acc": round(acc, 3),
        })
        emit(f"ota.{name}", seconds / rounds * 1e6,
             f"acc={acc:.3f}")
    by = {r["variant"]: r for r in records}
    emit("ota.vs_noma_speedup",
         by["ota"]["horizon_s"] / rounds * 1e6,
         f"{by['noma']['horizon_s'] / by['ota']['horizon_s']:.2f}x")
    return {
        "suite": "ota",
        "settings": {"m": m, "k": 3, "rounds": rounds,
                     "noise_std": NOISE_STD, "fast": bool(fast)},
        "records": records,
    }


if __name__ == "__main__":
    main()
