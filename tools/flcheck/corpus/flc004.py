"""FLC004 corpus: Python int arithmetic crossing jnp without a dtype.

The PR 7 bug: payload accounting at 10^8 params * 32 bits overflowed the
default int32 when the host int crossed into jnp.  Never executed —
parsed only.
"""
import jax.numpy as jnp

NUM_PARAMS = 10 ** 8


def bad_payload_bits(bits_per_param):
    return jnp.asarray(NUM_PARAMS * bits_per_param)  # expect: FLC004


def good_explicit_dtype(bits_per_param):
    return jnp.asarray(NUM_PARAMS * bits_per_param, dtype=jnp.float64)


def good_shape_derived(x):
    # shape products are bounded by the array's element count
    return jnp.asarray(x.shape[0] * x.shape[1])


def good_plain_value(n):
    # no arithmetic at the boundary: nothing to overflow mid-expression
    return jnp.asarray(n)
