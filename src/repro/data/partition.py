"""Non-iid device partitioning (paper §IV: "sizes and distributions both
differ"). Standard Dirichlet(alpha) class-mixture protocol + log-normal size
jitter (DESIGN.md §6.5)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    num_devices: int,
    *,
    alpha: float = 0.5,
    size_sigma: float = 0.4,
    min_per_device: int = 8,
    seed: int = 0,
):
    """Return list[num_devices] of index arrays into the dataset.

    Each device's class distribution ~ Dirichlet(alpha); device sizes are
    log-normal-jittered around the uniform share. Every sample is assigned to
    exactly one device.
    """
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for idx in by_class:
        rng.shuffle(idx)

    sizes = rng.lognormal(0.0, size_sigma, num_devices)
    sizes = np.maximum(
        (sizes / sizes.sum() * len(labels)).astype(int), min_per_device
    )
    mixes = rng.dirichlet(np.full(num_classes, alpha), num_devices)

    cursor = np.zeros(num_classes, dtype=int)
    shards = []
    for d in range(num_devices):
        want = np.round(mixes[d] * sizes[d]).astype(int)
        take = []
        for c in range(num_classes):
            avail = len(by_class[c]) - cursor[c]
            n = min(want[c], avail)
            take.append(by_class[c][cursor[c] : cursor[c] + n])
            cursor[c] += n
        shards.append(np.concatenate(take) if take else np.empty(0, int))
    # Distribute any leftovers round-robin so every sample lands somewhere.
    leftovers = np.concatenate(
        [by_class[c][cursor[c] :] for c in range(num_classes)]
    )
    for i, s in enumerate(np.array_split(leftovers, num_devices)):
        shards[i] = np.concatenate([shards[i], s])
    for d in range(num_devices):
        rng.shuffle(shards[d])
    return shards
