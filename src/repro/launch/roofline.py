"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (ROOFLINE ANALYSIS spec):

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the dry-run
unrolls layer scans so loop bodies are fully counted). collective_bytes is
parsed from the optimized HLO text: we sum *output shape* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op. cost_analysis (and the HLO) are per-SPMD-partition programs, so both
numerators are per-chip already; we therefore drop the /chips in the code
and document the terms as per-chip seconds (equivalent to the spec's
global-work / (chips * rate) when work is balanced).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[8,128]{1,0}' or a
    tuple '(f32[4], f32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # ops look like: %name = bf16[...]{...} all-gather(...), or fusion
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start" or op == kind + "-done":
                if op.endswith("-done"):
                    break  # counted at -start
                bytes_by[kind] += _shape_bytes(m.group(1))
                count_by[kind] += 1
                break
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops: float               # per-chip HLO flops
    hbm_bytes: float           # per-chip bytes accessed
    collective_bytes: float    # per-chip bytes through collectives
    collectives: CollectiveStats
    model_flops: float         # 6 * N_active * tokens (useful-work reference)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): remat/dispatch/attention waste."""
        return self.model_flops / max(self.flops, 1.0)

    def summary(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "collective_breakdown": dict(self.collectives.bytes_by_kind),
            "collective_counts": dict(self.collectives.count_by_kind),
            "model_flops_per_chip": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(cfg, shape, *, n_chips: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), per chip.

    For decode shapes D = global_batch tokens (one step); train includes the
    3x of backward (6 = 2 fwd + 4 bwd per param-token)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens / n_chips


def analyze(compiled, hlo_text: str, cfg, shape, *, n_chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(coll.total_bytes),
        collectives=coll,
        model_flops=model_flops(cfg, shape, n_chips=n_chips),
    )
