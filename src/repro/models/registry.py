"""Model registry: one uniform Model facade per architecture family."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.config import ModelConfig
from repro.models import encdec, hybrid, mamba2, moe, transformer, vlm
from repro.models.params import abstract_params, init_params, logical_specs

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    schema: Any
    module: Any
    shards: int

    # ---- params ----
    def init(self, key: jax.Array):
        return init_params(self.schema, key)

    def abstract(self):
        return abstract_params(self.schema)

    def param_logical_specs(self):
        return logical_specs(self.schema)

    # ---- compute ----
    def loss(self, params, batch, **kw):
        return self.module.loss_fn(params, batch, self.cfg, **kw)

    def forward(self, params, batch, **kw):
        extra = _modal_kwargs(self.cfg, batch)
        return self.module.forward(params, batch["tokens"], self.cfg, **extra, **kw)

    def init_cache(self, batch: int, max_len: int):
        return self.module.init_cache(self.cfg, batch, max_len, shards=self.shards)

    def decode_step(self, params, caches, tokens, *, batch=None, **kw):
        extra = _modal_kwargs(self.cfg, batch or {}, decode=True)
        return self.module.decode_step(params, caches, tokens, self.cfg, **extra, **kw)


def _modal_kwargs(cfg, batch, *, decode: bool = False):
    out = {}
    if cfg.family == "vlm":
        out["img_feats"] = batch["img_feats"]
    if cfg.family == "encdec":
        if decode:
            out["enc_out"] = batch["enc_out"]
        else:
            out["enc_feats"] = batch["enc_feats"]
    return out


def build_model(cfg: ModelConfig, *, shards: int = 1) -> Model:
    if cfg.family not in _FAMILIES:
        raise KeyError(f"unknown family {cfg.family!r}")
    module = _FAMILIES[cfg.family]
    return Model(cfg, module.schema(cfg, shards=shards), module, shards)
