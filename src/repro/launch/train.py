"""End-to-end distributed trainer with the paper's FL compression in-loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --fl-bits 8

Runs on whatever devices exist (1 CPU here; the production mesh path is
exercised by dryrun.py). Each step: sample synthetic token batch -> forward/
backward -> DoReFa-quantize gradients with bits from the NOMA rate model
(one simulated round per step, K = data-shard groups) -> AdamW.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.configs import get_config, get_smoke
from repro.core import channel as chan
from repro.core import noma
from repro.core import quantization as qlib
from repro.data import synthetic_token_batches
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.optim import adamw, linear_warmup_cosine
from repro.utils.tree import tree_count


def fl_bits_schedule(key, payload_bits: float, n_rounds: int,
                     cell: chan.CellConfig) -> np.ndarray:
    """Per-round uplink quantization bit-widths from the NOMA rate model.

    Each training step is one FL round: draw channels, schedule greedily by
    gain (the trainer's data-parallel groups stand in for the K clients),
    take the *minimum* scheduled rate as the binding budget (synchronous
    aggregation waits for the slowest client)."""
    dist = chan.sample_positions(key, cell)
    gains = chan.sample_round_channels(jax.random.fold_in(key, 1), dist, cell,
                                       n_rounds)
    bits = []
    for t in range(n_rounds):
        top = jnp.sort(gains[t])[-3:]  # K=3 best channels this round
        powers = jnp.full((3,), cell.max_power_w)
        budget = noma.bit_budget(powers, top, cell.noise_power_w,
                                 cell.bandwidth_hz, cell.slot_seconds)
        b = qlib.adaptive_bits(payload_bits, jnp.min(budget))
        bits.append(int(b))
    return np.array(bits)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fl-bits", type=int, default=None,
                    help="fixed uplink bits; default: adaptive from NOMA model")
    ap.add_argument("--no-fl", action="store_true", help="disable compression")
    ap.add_argument("--ef", action="store_true",
                    help="error-feedback quantization (beyond-paper; residual "
                         "compensation, fixed --fl-bits required)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None, help="checkpoint path (saved at end)")
    ap.add_argument("--save-every", type=int, default=0,
                    help="also checkpoint every N steps")
    ap.add_argument("--resume", default=None, help="checkpoint path to resume")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    start_step = 0
    n_params = tree_count(params)
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M family={cfg.family}")

    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps))
    if args.ef:
        from repro.core.compression import error_feedback_optimizer

        assert args.fl_bits is not None, "--ef needs a fixed --fl-bits"
        opt = error_feedback_optimizer(opt, args.fl_bits)
    opt_state = opt.init(params)

    if args.resume:
        from repro.checkpoint import load_checkpoint

        ckpt = load_checkpoint(args.resume)
        assert ckpt["arch"] == cfg.name, (ckpt["arch"], cfg.name)
        params, opt_state = ckpt["params"], ckpt["opt_state"]
        start_step = int(ckpt["step"])
        print(f"resumed from {args.resume} at step {start_step}")

    if args.no_fl:
        bits_per_round = np.full(args.steps, 32)
    elif args.fl_bits is not None:
        bits_per_round = np.full(args.steps, args.fl_bits)
    else:
        cell = chan.CellConfig()
        bits_per_round = fl_bits_schedule(
            jax.random.fold_in(key, 99), n_params * 32, args.steps, cell
        )
        print("adaptive fl bits:", bits_per_round[:10], "...")

    # one jitted step per distinct bit-width (static arg)
    step_cache = {}

    def get_step(bits):
        # with --ef the quantization lives inside the optimizer wrapper
        eff = None if (args.ef or bits >= 32) else int(bits)
        if bits not in step_cache:
            step_cache[bits] = jax.jit(
                steps_lib.make_train_step(model, opt, fl_bits=eff)
            )
        return step_cache[bits]

    def save(path, step):
        from repro.checkpoint import save_checkpoint

        save_checkpoint(path, {"arch": cfg.name, "step": step,
                               "params": params, "opt_state": opt_state})
        print(f"checkpoint -> {path} (step {step})")

    data = synthetic_token_batches(cfg.vocab_size, args.batch, args.seq,
                                   seed=args.seed)
    # keep the data stream aligned with the step counter on resume
    for _ in range(start_step):
        next(data)
    fkey = jax.random.fold_in(key, 7)
    losses = []
    t0 = time.time()
    for i in range(start_step, args.steps):
        tokens, labels = next(data)
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if cfg.family == "vlm":
            batch["img_feats"] = jax.random.normal(
                jax.random.fold_in(fkey, i),
                (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["enc_feats"] = jax.random.normal(
                jax.random.fold_in(fkey, i),
                (args.batch, max(args.seq // 4, 8), cfg.d_model), jnp.bfloat16)
        params, opt_state, loss = get_step(int(bits_per_round[i]))(
            params, opt_state, batch)
        losses.append(float(loss))
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f} bits {bits_per_round[i]}")
        if args.save_every and (i + 1) % args.save_every == 0 and args.save:
            save(args.save, i + 1)

    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if losses and losses[-1] >= losses[0]:
        print("WARNING: loss did not improve (expected for very low fl-bits "
              "or very short runs)")
    if args.save:
        save(args.save, args.steps)
    return losses


if __name__ == "__main__":
    main()
