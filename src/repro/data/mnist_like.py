"""Deterministic synthetic stand-in for MNIST (DESIGN.md §6.1).

The container is offline, so we synthesize a 10-class 28x28 grayscale
dataset whose difficulty is MNIST-like: each class is a mixture of 3
Gaussian-blob prototypes on the image grid plus pixel noise, which makes
classes linearly-separable-ish but not trivially so (LeNet-300-100 reaches
60-95% within a few hundred gradient steps, mirroring the paper's curves).

Fully deterministic given the seed; train/test split sizes follow Table I
(90% / 10%).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dataset:
    x_train: np.ndarray  # (N, 784) float32 in [0,1]
    y_train: np.ndarray  # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def num_classes(self) -> int:
        return int(self.y_train.max()) + 1


def _class_prototypes(rng: np.random.Generator, num_classes: int, blobs: int):
    """Per class: `blobs` Gaussian bumps (cx, cy, sigma, amp) on the 28x28 grid."""
    protos = []
    for _ in range(num_classes):
        cx = rng.uniform(5, 23, blobs)
        cy = rng.uniform(5, 23, blobs)
        sig = rng.uniform(1.5, 4.0, blobs)
        amp = rng.uniform(0.6, 1.0, blobs)
        protos.append((cx, cy, sig, amp))
    return protos


def _render(protos, jitter_rng: np.random.Generator, n: int):
    cx, cy, sig, amp = protos
    yy, xx = np.mgrid[0:28, 0:28]
    imgs = np.zeros((n, 28, 28), np.float32)
    for b in range(len(cx)):
        jx = cx[b] + jitter_rng.normal(0, 1.2, n)
        jy = cy[b] + jitter_rng.normal(0, 1.2, n)
        js = sig[b] * np.exp(jitter_rng.normal(0, 0.15, n))
        ja = amp[b] * np.exp(jitter_rng.normal(0, 0.2, n))
        d2 = (xx[None] - jx[:, None, None]) ** 2 + (yy[None] - jy[:, None, None]) ** 2
        imgs += ja[:, None, None] * np.exp(-d2 / (2 * js[:, None, None] ** 2))
    imgs += jitter_rng.normal(0, 0.12, imgs.shape)
    return np.clip(imgs, 0.0, 1.0).reshape(n, 784).astype(np.float32)


def make_mnist_like(
    *,
    num_samples: int = 12_000,
    num_classes: int = 10,
    train_frac: float = 0.9,
    seed: int = 0,
) -> Dataset:
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng, num_classes, blobs=3)
    per_class = num_samples // num_classes
    xs, ys = [], []
    for c in range(num_classes):
        xs.append(_render(protos[c], np.random.default_rng(seed * 1000 + c), per_class))
        ys.append(np.full(per_class, c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    x, y = x[perm], y[perm]
    n_train = int(train_frac * len(x))
    return Dataset(x[:n_train], y[:n_train], x[n_train:], y[n_train:])
