"""Scheduler edge cases (T*K > M horizons) and the jax backend equivalence.

Regression coverage for the crash/bias sweep: every scheduler must survive
horizons that exhaust the device set (Yang et al. 2019 comparison regime),
emitting empty tail groups instead of crashing; and the device-resident
greedy (``backend="jax"``) must reproduce the numpy path bit-for-bit.
"""
import numpy as np
import pytest

from repro.core import scheduling

NOISE = 1.6e-14


def _instance(m, t, seed):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    return gains, w


def _make(name, gains, w, k):
    if name == "lazy-gwmin":
        return scheduling.lazy_greedy_schedule(gains, w, k, noise_power=NOISE)
    if name == "literal-gwmin":
        return scheduling.literal_graph_schedule(gains, w, k, noise_power=NOISE)
    if name == "random":
        rng = np.random.default_rng(0)
        return scheduling.random_schedule(rng, gains, w, k, noise_power=NOISE)
    if name == "round-robin":
        return scheduling.round_robin_schedule(gains, w, k, noise_power=NOISE)
    if name == "proportional-fair":
        return scheduling.proportional_fair_schedule(gains, w, k, noise_power=NOISE)
    raise ValueError(name)


# --------------------------------------------------------------------------
# T*K > M: the horizon exhausts the device set
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name",
    ["lazy-gwmin", "literal-gwmin", "random", "round-robin", "proportional-fair"],
)
@pytest.mark.parametrize("m,t,k", [(5, 4, 2), (4, 3, 2), (6, 8, 1)])
def test_tk_exceeds_m_no_crash(name, m, t, k):
    """All five schedulers must survive T*K > M: C1/C2 hold, every id is in
    range, and rounds beyond the device supply come back empty, not bogus."""
    gains, w = _instance(m, t, seed=3)
    sched = _make(name, gains, w, k)
    assert sched.validate(m, k)
    assert len(sched.rounds) == t
    assert all(len(grp) <= k for grp in sched.rounds)
    # no device can appear anywhere once all M are used
    assert sum(len(grp) for grp in sched.rounds) <= m
    assert len(sched.scheduled_devices()) == sum(len(g) for g in sched.rounds)


@pytest.mark.parametrize("name", ["round-robin", "proportional-fair"])
def test_exhausting_schedulers_cover_all_devices_then_go_empty(name):
    """The sequential policies schedule every device and then emit () tails
    (proportional-fair used to crash here: an empty ``avail`` built with
    ``np.array([])`` is float64 and rejects fancy indexing)."""
    m, t, k = 4, 3, 2
    gains, w = _instance(m, t, seed=7)
    sched = _make(name, gains, w, k)
    assert sched.scheduled_devices() == set(range(m))
    assert sched.rounds[-1] == ()


def test_proportional_fair_empty_avail_regression():
    """Direct regression for src/repro/core/scheduling.py PF indexing: with
    T*K well past M the scheduler iterates many all-empty rounds."""
    gains, w = _instance(3, 6, seed=0)
    sched = scheduling.proportional_fair_schedule(gains, w, 2, noise_power=NOISE)
    assert sched.validate(3, 2)
    assert sched.rounds[2:] == [(), (), (), ()]


# --------------------------------------------------------------------------
# backend="jax": device-resident greedy == numpy greedy, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,t,pool,seed",
    [
        (8, 2, 3, 24, 0),      # pool >= M: full enumeration
        (12, 3, 3, 24, 1),
        (32, 3, 4, 24, 2),     # proxy-ranked pool (M > pool)
        (24, 3, 4, 8, 3),
        (32, 2, 5, 8, 4),
        (5, 2, 4, 24, 5),      # T*K > M: host tail path for leftover groups
        (30, 3, 11, 8, 6),     # T*K > M with proxy pool
    ],
)
def test_jax_backend_bit_identical(m, k, t, pool, seed):
    pytest.importorskip("jax")
    gains, w = _instance(m, t, seed)
    a = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool, backend="jax"
    )
    assert a.rounds == b.rounds
    for pa, pb in zip(a.powers, b.powers):
        np.testing.assert_array_equal(pa, pb)
    for ra, rb in zip(a.rates, b.rates):
        np.testing.assert_array_equal(ra, rb)
    assert a.weighted_sum_rate == b.weighted_sum_rate
    assert b.validate(m, k)


def test_jax_backend_bit_identical_with_mapel_refinement():
    """Selection equality carries through the batched MAPEL finalization."""
    pytest.importorskip("jax")
    gains, w = _instance(10, 3, seed=11)
    a = scheduling.lazy_greedy_schedule(
        gains, w, 2, power_mode="mapel", noise_power=NOISE
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, 2, power_mode="mapel", noise_power=NOISE, backend="jax"
    )
    assert a.rounds == b.rounds
    for pa, pb in zip(a.powers, b.powers):
        np.testing.assert_array_equal(pa, pb)
    assert a.weighted_sum_rate == b.weighted_sum_rate


def test_unknown_backend_raises():
    gains, w = _instance(6, 2, seed=0)
    with pytest.raises(ValueError, match="backend"):
        scheduling.lazy_greedy_schedule(
            gains, w, 2, noise_power=NOISE, backend="tpu-v9"
        )
