"""Encoder-decoder transformer for the audio arch (seamless-m4t backbone)
[arXiv:2308.11596].

Per the carve-out the codec/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model) from ``input_specs()``.
Encoder: bidirectional self-attention stack. Decoder: causal self-attention
+ cross-attention to the encoder output + SwiGLU MLP. Decode caches the
decoder self-attention KV and the (fixed) encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import ParamSpec, stacked


def dec_block_schema(cfg, *, shards: int = 16):
    return {
        "ln1": L.rmsnorm_schema(cfg.d_model),
        "self_attn": L.attention_schema(cfg, shards=shards),
        "ln_x": L.rmsnorm_schema(cfg.d_model),
        "cross_attn": L.attention_schema(cfg, shards=shards),
        "ln2": L.rmsnorm_schema(cfg.d_model),
        "mlp": L.mlp_schema(cfg.d_model, cfg.d_ff),
    }


def schema(cfg, *, shards: int = 16):
    return {
        "enc_in": ParamSpec((cfg.d_model, cfg.d_model), ("embed", None)),
        "encoder": stacked(T.block_schema(cfg, shards=shards), cfg.encoder_layers),
        "enc_ln": L.rmsnorm_schema(cfg.d_model),
        "embed": L.embedding_schema(cfg.padded_vocab, cfg.d_model, tie=cfg.tie_embeddings),
        "decoder": stacked(dec_block_schema(cfg, shards=shards), cfg.num_layers),
        "ln_f": L.rmsnorm_schema(cfg.d_model),
    }


def encode(params, enc_feats, cfg, *, kv_chunk: int = 1024, remat: bool = True,
           unroll: bool = False):
    """enc_feats: (B, S_enc, D) stub frame embeddings -> encoder output."""
    x = jnp.einsum(
        "bsd,de->bse", enc_feats.astype(L.COMPUTE_DTYPE),
        params["enc_in"].astype(L.COMPUTE_DTYPE),
    )
    mspec = L.AttnMaskSpec(causal=False)
    positions = jnp.arange(enc_feats.shape[1])

    def body(x, p_layer):
        y, _ = T.transformer_block(
            p_layer, x, cfg, mspec=mspec, positions=positions,
            cache=None, kv_chunk=kv_chunk,
        )
        return y, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"], unroll=unroll)
    return L.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def decoder_block(p, x, enc_out, cfg, *, positions, cache, kv_chunk):
    h, new_cache = L.attention_block(
        p["self_attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
        mask_spec=L.AttnMaskSpec(causal=True), positions=positions,
        cache=cache, kv_chunk=kv_chunk,
    )
    x = x + h
    h, _ = L.attention_block(
        p["cross_attn"], L.rmsnorm(p["ln_x"], x, cfg.norm_eps), cfg,
        mask_spec=L.AttnMaskSpec(causal=False), kv_source=enc_out,
        kv_chunk=kv_chunk,
    )
    x = x + h
    x = x + L.mlp_block(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, new_cache


def forward(params, tokens, cfg, *, enc_feats=None, enc_out=None, caches=None,
            kv_chunk: int = 1024, remat: bool = True, unroll: bool = False, **_):
    if enc_out is None:
        enc_out = encode(params, enc_feats, cfg, kv_chunk=kv_chunk, remat=remat,
                         unroll=unroll)
    x = L.embed(params["embed"], tokens)
    positions = None
    if caches is not None:
        positions = caches["len"][0] + jnp.arange(tokens.shape[1])[None, :]

    def body(x, xs):
        p_layer, cache = xs
        return decoder_block(
            p_layer, x, enc_out, cfg, positions=positions,
            cache=cache, kv_chunk=kv_chunk,
        )

    fn = jax.checkpoint(body) if (remat and caches is None) else body
    x, new_caches = jax.lax.scan(fn, x, (params["decoder"], caches), unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, tie=cfg.tie_embeddings)
    return logits, new_caches


def loss_fn(params, batch, cfg, **kw):
    logits, _ = forward(params, batch["tokens"], cfg,
                        enc_feats=batch["enc_feats"], **kw)
    return L.cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int, *, shards: int = 16):
    one = L.init_attn_cache(cfg, batch, max_len, shards=shards)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers, *x.shape)), one
    )


def decode_step(params, caches, tokens, cfg, *, enc_out, kv_chunk: int = 4096,
                unroll: bool = False):
    """enc_out: precomputed encoder output (run `encode` once at prefill)."""
    logits, new_caches = forward(
        params, tokens, cfg, enc_out=enc_out, caches=caches,
        kv_chunk=kv_chunk, remat=False, unroll=unroll,
    )
    return logits, new_caches
