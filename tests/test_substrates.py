"""Optimizers, data pipeline, checkpointing, channel model."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import channel
from repro.data import (
    ClientBank, dirichlet_partition, make_mnist_like, synthetic_token_batches,
)
from repro.optim import adam, adamw, momentum, sgd
from repro.optim.schedules import cosine_decay, linear_warmup_cosine


# ---- optimizers ----------------------------------------------------------

def _quadratic_min(opt, steps=400):
    target = jnp.asarray([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    return float(loss(params))


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adam(0.05),
                                 adamw(0.05, weight_decay=0.0)])
def test_optimizers_minimize_quadratic(opt):
    assert _quadratic_min(opt) < 1e-3


def test_adam_matches_reference_numpy():
    """One Adam step against a hand-written numpy reference."""
    g = np.array([0.3, -0.2], np.float32)
    p = np.array([1.0, 1.0], np.float32)
    lr, b1, b2, eps = 0.1, 0.9, 0.999, 1e-8
    m = (1 - b1) * g
    v = (1 - b2) * g**2
    ref = p - lr * (m / (1 - b1)) / (np.sqrt(v / (1 - b2)) + eps)

    opt = adam(lr, b1, b2, eps)
    params = {"w": jnp.asarray(p)}
    state = opt.init(params)
    new, _ = opt.update({"w": jnp.asarray(g)}, state, params)
    np.testing.assert_allclose(np.asarray(new["w"]), ref, rtol=1e-5)


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 110)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(s(jnp.asarray(110))) < 0.2
    c = cosine_decay(1.0, 100)
    assert float(c(jnp.asarray(0))) == pytest.approx(1.0)


# ---- data ----------------------------------------------------------------

def test_partition_is_exact_cover(rng):
    labels = rng.integers(0, 10, 997).astype(np.int32)
    shards = dirichlet_partition(labels, 13, seed=0)
    all_idx = np.concatenate(shards)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)


def test_partition_non_iid(rng):
    labels = rng.integers(0, 10, 5000).astype(np.int32)
    shards = dirichlet_partition(labels, 20, alpha=0.2, seed=0)
    # class distributions should differ across devices (non-iid)
    dists = []
    for s in shards:
        h = np.bincount(labels[s], minlength=10) / max(len(s), 1)
        dists.append(h)
    dists = np.array(dists)
    assert np.mean(np.std(dists, axis=0)) > 0.05
    sizes = np.array([len(s) for s in shards])
    assert sizes.std() > 0  # sizes differ too


def test_partition_enforces_realized_floor():
    """Regression (failing-before): the ``min_per_device`` clamp applied to
    *target* sizes before class pools were exhausted, and the leftover
    round-robin only topped up the first devices — late devices could
    realize shards far below the floor (this instance used to produce a
    3-sample shard).  The floor must hold on realized shards."""
    labels = np.random.default_rng(0).integers(0, 10, 300).astype(np.int64)
    shards = dirichlet_partition(labels, 24, alpha=0.3, size_sigma=1.0, seed=0)
    sizes = np.array([len(s) for s in shards])
    assert sizes.min() >= 8, f"realized shard below floor: {sizes.min()}"
    # still an exact cover after rebalancing
    all_idx = np.concatenate(shards)
    assert len(all_idx) == 300 and len(np.unique(all_idx)) == 300


def test_partition_floor_clamps_when_infeasible():
    """num_devices * min_per_device > n: the floor degrades to
    n // num_devices instead of dropping or duplicating samples."""
    labels = (np.arange(20) % 4).astype(np.int64)
    shards = dirichlet_partition(labels, 10, min_per_device=8, seed=1)
    sizes = np.array([len(s) for s in shards])
    assert sizes.min() >= 2 and sizes.sum() == 20
    assert len(np.unique(np.concatenate(shards))) == 20


def test_client_bank_matches_legacy_padding():
    """The bank's batch grid holds exactly the samples the legacy
    ``local_update`` padding would put there: shard order preserved,
    label -1 past each shard's end, global n_batches = max shard's."""
    ds = make_mnist_like(num_samples=600, seed=0)
    shards = dirichlet_partition(ds.y_train, 6, seed=0)
    bank = ClientBank.build(ds.x_train, ds.y_train, shards, batch_size=10)
    sizes = np.array([len(s) for s in shards])
    nb = -(-sizes.max() // 10)
    assert bank.xb.shape == (6, nb, 10, 784)
    assert bank.yb.shape == (6, nb, 10)
    np.testing.assert_array_equal(bank.sizes, sizes)
    for k, idx in enumerate(shards):
        flat_x = np.asarray(bank.xb[k]).reshape(-1, 784)
        flat_y = np.asarray(bank.yb[k]).reshape(-1)
        np.testing.assert_array_equal(flat_x[: len(idx)], ds.x_train[idx])
        np.testing.assert_array_equal(flat_y[: len(idx)], ds.y_train[idx])
        assert np.all(flat_y[len(idx):] == -1)
        assert np.all(flat_x[len(idx):] == 0.0)


def test_mnist_like_deterministic_and_learnable():
    a = make_mnist_like(num_samples=1000, seed=3)
    b = make_mnist_like(num_samples=1000, seed=3)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    assert a.x_train.shape[1] == 784
    assert 0.85 <= a.x_train.max() <= 1.0
    # 90/10 split (Table I)
    assert len(a.x_train) == 900 and len(a.x_test) == 100


def test_token_stream_deterministic():
    it1 = synthetic_token_batches(512, 2, 16, seed=7)
    it2 = synthetic_token_batches(512, 2, 16, seed=7)
    t1, l1 = next(it1)
    t2, l2 = next(it2)
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (2, 16) and l1.shape == (2, 16)
    np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])


# ---- checkpoint ----------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "b": jnp.ones(3, jnp.bfloat16)},
        "step": 7,
        "names": ["a", "b"],
        "nested": (jnp.zeros(2, jnp.int32), None),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack.zst")
        save_checkpoint(path, tree)
        back = load_checkpoint(path)
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert back["params"]["b"].dtype == jnp.bfloat16
    assert back["step"] == 7 and back["names"] == ["a", "b"]
    assert back["nested"][1] is None


# ---- channel -------------------------------------------------------------

def test_channel_pathloss_monotone_in_distance():
    cfg = channel.CellConfig()
    d = jnp.asarray([50.0, 100.0, 400.0])
    g = channel.large_scale_gain(d, cfg)
    assert float(g[0]) > float(g[1]) > float(g[2])


def test_rayleigh_unit_power():
    cfg = channel.CellConfig()
    h = channel.sample_small_scale(jax.random.PRNGKey(0), (200_000,))
    assert float(jnp.mean(h**2)) == pytest.approx(1.0, rel=0.02)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_positions_within_cell(seed):
    cfg = channel.CellConfig(num_devices=50)
    d = np.asarray(channel.sample_positions(jax.random.PRNGKey(seed), cfg))
    assert np.all(d >= cfg.min_distance_m) and np.all(d <= cfg.cell_radius_m)


def test_downlink_time_survives_f32_snr_underflow():
    """Regression (failing-before): a far device under a high path-loss
    exponent has a gain whose *square* underflows float32, which zeroed the
    downlink SNR, the rate, and returned T_d = inf — silently poisoning the
    Fig. 5 time axis.  The computation now runs in float64 (log1p), like
    the uplink rate engine."""
    cfg = channel.CellConfig()
    gains = jnp.asarray([1e-3, 1e-25], jnp.float32)  # (1e-25)^2 == 0 in f32
    t = channel.downlink_time_seconds(1e6, gains, cfg)
    assert np.isfinite(t) and t > 0


def test_downlink_time_zero_gain_raises():
    """A genuinely unreachable device (zero gain) is an error, not inf."""
    cfg = channel.CellConfig()
    with pytest.raises(ValueError, match="zero downlink SNR"):
        channel.downlink_time_seconds(1e6, jnp.asarray([1e-3, 0.0]), cfg)


def test_noise_power_matches_dbm():
    cfg = channel.CellConfig()
    # -174 dBm/Hz * 4 MHz = -174 + 10log10(4e6) ~= -107.98 dBm
    expected = 10 ** ((-174 + 10 * np.log10(4e6)) / 10) * 1e-3
    assert cfg.noise_power_w == pytest.approx(expected, rel=1e-6)


def test_trainer_checkpoint_resume(tmp_path):
    """train N steps + save == train k, save, resume, train N-k (same data)."""
    from repro.launch.train import main as train_main

    ck1 = str(tmp_path / "a.ckpt")
    ck2 = str(tmp_path / "b.ckpt")
    base = ["--arch", "qwen2-0.5b", "--smoke", "--batch", "2", "--seq", "32",
            "--fl-bits", "8"]
    train_main([*base, "--steps", "6", "--save", ck1])
    train_main([*base, "--steps", "3", "--save", ck2])
    train_main([*base, "--steps", "6", "--resume", ck2, "--save", ck2])

    a = load_checkpoint(ck1)
    b = load_checkpoint(ck2)
    assert a["step"] == b["step"] == 6
    for x, y in zip(jax.tree_util.tree_leaves(a["params"]),
                    jax.tree_util.tree_leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=2e-5, rtol=2e-5)
