"""Federated learning runtime (paper Algorithm 1 + §IV simulation).

Faithful paper-scale FedAvg over the simulated NOMA cell:
  per round t:
    1. PS broadcasts theta^t (downlink timing model, no compression).
    2. The scheduler assigns K devices to round t.  Precomputed policies
       (MWIS schedule over the whole horizon, the §IV baselines) planned
       this before training started; online policies (``policy.online``,
       e.g. update-aware / age-fair) are called *here*, inside the loop,
       reading the previous rounds' update norms, participation counts,
       and realized rates from a ``scheduling.Observation``.
    3. Each scheduled device runs local SGD on its own non-iid shard and
       produces a model delta.
    4. The uplink rate of each device sets the bit budget c_k = R_k * B * t;
       the delta is DoReFa-quantized to b_k = floor(32 / r_k) bits (paper
       §II-B).  Under NOMA that is the SIC rate over the shared slot; under
       TDMA each device gets its interference-free rate over its own
       sub-slot (adaptive compression applies to both uplinks — comparing a
       compressed NOMA run against an uncompressed TDMA run would bias the
       Fig. 5 comparison).
    5. PS aggregates: theta^{t+1} = theta^t + sum_k w_k * dq(delta_k),
       w_k = |D_k| / sum_selected |D_k| (weighted FedAvg; see DESIGN.md §6
       on the paper's line-10 notation).
  Timing: NOMA round = t_slot + T_d; TDMA round = K * t_slot + T_d (§IV).

Two round-body engines implement steps 3-5, selected by
``FLConfig.fl_engine`` (this module owns the driver — scheduling, power,
budgets, timing, and logging are computed once and shared by both):

  * ``"legacy"`` — :func:`_legacy_round`: one host-level ``local_update``
    per scheduled device (K shard uploads + K jitted scans + K eager
    quantize passes + host ``tree_map`` aggregation per round).  Simple,
    transparent, and kept as the **oracle** the batched engine is pinned
    against (``tests/test_fl_engine.py``).
  * ``"batched"`` — :class:`repro.core.fl_engine.BatchedRoundEngine`: all
    M shards live on device in a ``ClientBank`` and the whole round body
    (K-row gather -> vmapped local SGD -> batched norms -> traced
    per-client adaptive quantization -> weighted aggregation) is **one
    jitted dispatch**.  Aggregation uses an XLA einsum by default or the
    fused dequant+aggregate Pallas kernel under ``FLConfig.use_pallas``.
    Same schedules, same bit-widths, accuracies equal to f32 tolerance;
    use it for large-M / large-K sweeps (BENCH_fl.json tracks the
    round-loop speedup).

The per-client SGD math itself lives in one place —
``fl_engine.sgd_epoch`` — which the legacy path jits per device and the
batched engine vmaps over the client axis.

The LLM-scale integration of the same compression lives in
repro/launch/train.py (quantized-DSGD inside the pjit'd step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import channel as chan
from repro.core import compression, fl_engine, noma, scheduling
from repro.core import power as power_lib
from repro.core import quantization as qlib
from repro.models import lenet
from repro.utils.tree import tree_count


@dataclasses.dataclass
class RoundLog:
    round: int
    devices: tuple
    rates: np.ndarray            # spectral efficiency per scheduled device
    bits: np.ndarray             # quantization bit-widths used
    compression_ratios: np.ndarray
    test_accuracy: float
    wall_time_s: float           # cumulative simulated communication time


@dataclasses.dataclass
class FLResult:
    logs: list
    final_params: dict
    scheme: str

    def accuracies(self):
        return np.array([l.test_accuracy for l in self.logs])

    def times(self):
        return np.array([l.wall_time_s for l in self.logs])


# --------------------------------------------------------------------------
# Local training (LeNet on device shards)
# --------------------------------------------------------------------------

# One jitted epoch per device — the same per-client math the batched engine
# vmaps; the single implementation lives in fl_engine.sgd_epoch (``unroll``
# is a scan parameter, hence static under jit).
_sgd_epoch = jax.jit(fl_engine.sgd_epoch, static_argnames="unroll")


def local_update(params, xs, ys, cfg: FLConfig):
    """Run local epochs; returns the model delta (new - old)."""
    n = len(xs)
    bs = cfg.batch_size
    n_batches = max(1, (n + bs - 1) // bs)
    pad = n_batches * bs - n
    xp = np.concatenate([xs, np.zeros((pad, xs.shape[1]), xs.dtype)])
    yp = np.concatenate([ys, np.full(pad, -1, ys.dtype)])
    xb = jnp.asarray(xp.reshape(n_batches, bs, -1))
    yb = jnp.asarray(yp.reshape(n_batches, bs))
    new = params
    for _ in range(cfg.local_epochs):
        new = _sgd_epoch(new, xb, yb, cfg.learning_rate)
    return jax.tree_util.tree_map(lambda a, b: a - b, new, params)


def _legacy_round(
    params, devs, budgets, agg_w, dataset, shards, cfg: FLConfig, payload,
    *, need_norms: bool,
):
    """The per-device host round body (steps 3-5), kept as the oracle.

    One ``local_update`` + quantize pass per scheduled device, host
    ``tree_map`` aggregation.  Returns ``(params, bits_used, ratios,
    norms)`` — the same contract as ``BatchedRoundEngine.run_round``.
    """
    deltas, bits_used, ratios, norms = [], [], [], []
    for j, d in enumerate(devs):
        idx = shards[d]
        delta = local_update(params, dataset.x_train[idx], dataset.y_train[idx], cfg)
        if need_norms:
            # the policies' norm signal is the raw local update, taken
            # before quantization (Amiri et al. rank by what the device
            # computed, not by what the channel let through); policies
            # that never read obs.update_norms skip the per-device
            # reduction + host sync entirely
            norms.append(_tree_l2(delta))
        if cfg.compression == "adaptive":
            # NOMA: SIC rate over the shared slot; TDMA: interference-free
            # rate over the device's own sub-slot. Both budgets are in
            # ``budgets`` — quantizing only the NOMA uplink would bias
            # the Fig. 5 comparison in TDMA's favour.
            b = int(qlib.adaptive_bits(payload, budgets[j]))
            delta = compression.encode_decode_tree(
                delta, b, paper_exact=cfg.paper_exact_range
            )
            bits_used.append(b)
            ratios.append(float(qlib.compression_ratio(payload, budgets[j])))
        else:
            bits_used.append(32)
            ratios.append(1.0)
        deltas.append(delta)

    if deltas:
        update = jax.tree_util.tree_map(
            lambda *ds: sum(w * d for w, d in zip(agg_w, ds)), *deltas
        )
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, update)
    # else: empty round (T*K > M schedules legitimately produce empty
    # tail groups) — no uplink, no aggregation.
    return params, bits_used, ratios, norms


# --------------------------------------------------------------------------
# Scheduling front-end
# --------------------------------------------------------------------------

def policy_config(cell: chan.CellConfig, cfg: FLConfig) -> scheduling.PolicyConfig:
    """PolicyConfig from the FL settings + the cell physics."""
    return scheduling.PolicyConfig(
        group_size=cfg.group_size,
        power_mode=cfg.power_mode,
        pmax=cell.max_power_w,
        noise_power=cell.noise_power_w,
        backend=cfg.scheduler_backend,
        seed=cfg.seed,
    )


def make_schedule(
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    cell: chan.CellConfig,
    cfg: FLConfig,
    policy: "scheduling.SchedulerPolicy | None" = None,
) -> scheduling.Schedule:
    """One-shot schedule via the policy registry (string if/elif retired).

    ``policy`` lets a caller that already resolved ``cfg.scheduler`` (e.g.
    ``run_federated_learning``) reuse the instance.  For online policies
    this drives ``select_round`` with rate/participation feedback only (no
    FL state outside the training loop) — the live path in
    :func:`run_federated_learning` is the real deal.
    """
    if policy is None:
        policy = scheduling.get_policy(cfg.scheduler)
    return scheduling.build_schedule(
        policy, gains_tm, weights_m, policy_config(cell, cfg)
    )


def _tree_l2(tree) -> float:
    """||tree||_2 over all leaves (the update-aware policies' norm signal).

    The squared dots accumulate on device; the single ``float()`` at the end
    is the only host sync (this runs per scheduled device per live round).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    return float(jnp.sqrt(sum(jnp.vdot(leaf, leaf) for leaf in leaves)))


# --------------------------------------------------------------------------
# Main simulation
# --------------------------------------------------------------------------

def run_federated_learning(
    dataset,
    shards: list,
    cell: chan.CellConfig,
    cfg: FLConfig,
    *,
    uplink: str = "noma",            # "noma" | "tdma"
    schedule: Optional[scheduling.Schedule] = None,
    eval_every: int = 1,
    progress: Optional[Callable[[RoundLog], None]] = None,
) -> FLResult:
    """Simulate the full FL process; returns per-round logs.

    dataset: repro.data.mnist_like.Dataset; shards: per-device index lists.
    """
    key = jax.random.PRNGKey(cfg.seed)
    params = lenet.schema()
    from repro.models.params import init_params

    params = init_params(params, key)
    payload = tree_count(params) * 32  # I: full-precision payload bits

    sizes = np.array([len(s) for s in shards], dtype=np.float64)
    weights = sizes / sizes.sum()

    # Round-body engine: "batched" folds steps 3-5 into one jitted dispatch
    # per round over a device-resident ClientBank; None selects the legacy
    # per-device host loop (the oracle — see module docstring).
    engine = None
    if cfg.fl_engine == "batched":
        engine = fl_engine.BatchedRoundEngine(dataset, shards, cfg, payload)

    # channel realizations for the whole horizon
    dist = chan.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(
        chan.sample_round_channels(jax.random.fold_in(key, 2), dist, cell,
                                   cfg.num_rounds)
    )

    # Scheduling: precomputed policies (and caller-supplied schedules) fix
    # the whole horizon now; online policies run live inside the round loop.
    policy = obs = policy_state = allocator = None
    if schedule is None:
        policy = scheduling.get_policy(cfg.scheduler)
        if getattr(policy, "online", False):
            pcfg = policy_config(cell, cfg)
            policy_state = policy.init_state(gains, weights, pcfg)
            obs = scheduling.Observation.initial(cell.num_devices)
            allocator = power_lib.make_power_allocator(
                cfg.power_mode, cell.max_power_w, cell.noise_power_w
            )
        else:
            # one owner for precomputed construction (validated inside
            # build_schedule with the policy's own C1 expectation),
            # reusing the instance resolved above
            schedule = make_schedule(gains, weights, cell, cfg, policy=policy)
            policy = None
    else:
        # Caller-supplied schedule: its own allow_revisits flag (set by
        # build_schedule from the producing policy, or by the caller for a
        # hand-rolled revisiting schedule) decides C1 strictness.
        schedule.validate(cell.num_devices, cfg.group_size)

    # Downlink broadcast time on the large-scale gain only: the paper's
    # Fig. 5 time scale (35 rounds in ~10-22 s) implies a fading-free
    # downlink; with per-round Rayleigh draws the worst faded user's T_d
    # dominates both schemes and masks the NOMA/TDMA uplink gap.
    dl_gains = chan.large_scale_gain(dist, cell)
    dl_time = float(chan.downlink_time_seconds(payload, dl_gains, cell))

    x_test = jnp.asarray(dataset.x_test)
    y_test = jnp.asarray(dataset.y_test)
    acc_fn = jax.jit(lenet.accuracy)

    logs = []
    t_wall = 0.0
    for t in range(cfg.num_rounds):
        if policy is not None:   # live mode: select with FL-state feedback
            group, policy_state = policy.select_round(t, policy_state, obs)
            devs = tuple(int(d) for d in group)
            scheduling.validate_group(
                devs, cell.num_devices, cfg.group_size,
                label=f"round-{t} group from policy {policy.name!r}",
            )
            powers_t, rates = scheduling.finalize_round(
                devs, t, gains, weights, allocator, cell.noise_power_w
            )
        else:
            devs = schedule.rounds[t]
            powers_t = schedule.powers[t]
            rates = schedule.rates[t]  # spectral efficiency (bit/s/Hz)
        if uplink == "tdma":
            # each device alone in its sub-slot, interference-free
            p = powers_t
            g = gains[t, list(devs)]
            rates = np.asarray(
                noma.tdma_rates(jnp.asarray(p), jnp.asarray(g), cell.noise_power_w)
            )
            slot = cell.slot_seconds  # each scheduled device gets a full slot
            budgets = rates * cell.bandwidth_hz * slot
            # airtime = one sub-slot per *scheduled* device: empty/partial
            # T*K > M tail rounds must not be charged the full K sub-slots
            # (that skewed the Fig. 5 time axis against TDMA tails)
            round_time = len(devs) * cell.slot_seconds + dl_time
        else:
            budgets = rates * cell.bandwidth_hz * cell.slot_seconds
            # the shared NOMA uplink slot is only spent when someone
            # transmits — empty T*K > M tail rounds cost downlink only
            # (mirrors the TDMA per-device sub-slot accounting above)
            uplink_time = cell.slot_seconds if devs else 0.0
            round_time = uplink_time + dl_time

        # FedAvg weights w_k = |D_k| / sum_selected |D_k| — computed here so
        # both engines aggregate with identical host-float64 values
        raw_w = [sizes[d] for d in devs]
        agg_w = np.asarray(raw_w) / max(sum(raw_w), 1.0)
        need_norms = policy is not None and getattr(policy, "needs_norms", True)
        if engine is not None:
            params, bits_used, ratios, norms = engine.run_round(
                params, devs, budgets, agg_w, need_norms=need_norms
            )
        else:
            params, bits_used, ratios, norms = _legacy_round(
                params, devs, budgets, agg_w, dataset, shards, cfg, payload,
                need_norms=need_norms,
            )
        # empty rounds (T*K > M schedules legitimately produce empty tail
        # groups) train/aggregate nothing; the wall clock still advances and
        # the round is still logged below.

        if policy is not None:
            # feed realized norms/rates back for the next select_round
            # (norms is empty when the policy declared needs_norms=False)
            obs = obs.record_round(t, devs, np.asarray(rates),
                                   norms if norms else None)

        t_wall += round_time
        # the final round is always evaluated: accuracies()[-1] must measure
        # the final model even when eval_every skips over num_rounds - 1
        do_eval = t % eval_every == 0 or t == cfg.num_rounds - 1
        acc = float(acc_fn(params, x_test, y_test)) if do_eval else logs[-1].test_accuracy
        log = RoundLog(t, tuple(devs), np.asarray(rates), np.asarray(bits_used),
                       np.asarray(ratios), acc, t_wall)
        logs.append(log)
        if progress:
            progress(log)

    scheme = f"{uplink}/{cfg.scheduler}/{cfg.power_mode}/{cfg.compression}"
    return FLResult(logs, params, scheme)
