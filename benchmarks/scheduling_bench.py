"""Algorithm 2 scaling: literal graph vs lazy column generation, and the
greedy's optimality gap vs brute force (paper §III)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import scheduling

NOISE = 1.6e-14


def _instance(m, t, seed=0):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    return gains, w


def main(fast: bool = False):
    # literal vs lazy at small M (identical outputs; timing gap)
    gains, w = _instance(8, 3)
    us_lit = timeit(lambda: scheduling.literal_graph_schedule(
        gains, w, 2, noise_power=NOISE), repeats=3)
    us_lazy = timeit(lambda: scheduling.lazy_greedy_schedule(
        gains, w, 2, noise_power=NOISE), repeats=3)
    emit("sched.literal_M8", us_lit, "explicit C(M,K)*T graph")
    emit("sched.lazy_M8", us_lazy, f"speedup {us_lit / us_lazy:.1f}x")

    # optimality gap vs brute force
    gaps = []
    for seed in range(5):
        g2, w2 = _instance(6, 2, seed)
        greedy = scheduling.lazy_greedy_schedule(g2, w2, 2, noise_power=NOISE)
        best = scheduling.brute_force_schedule(g2, w2, 2, noise_power=NOISE)
        gaps.append(greedy.weighted_sum_rate / best.weighted_sum_rate)
    emit("sched.greedy_vs_optimal", 0.0, f"ratio {np.mean(gaps):.3f}")

    # paper scale: M=300, K=3, T=35 (infeasible for the literal graph:
    # C(300,3)*35 = 1.55e8 vertices)
    m, t = (100, 10) if fast else (300, 35)
    gains, w = _instance(m, t)
    t0 = time.perf_counter()
    s = scheduling.lazy_greedy_schedule(gains, w, 3, noise_power=NOISE)
    us = (time.perf_counter() - t0) * 1e6
    emit(f"sched.lazy_M{m}_T{t}", us,
         f"wsum {s.weighted_sum_rate:.3f} literal_would_need "
         f"{35 * 4455100 if not fast else 10 * 161700} vertices")
    s.validate(m, 3)


if __name__ == "__main__":
    main()
