"""The paper's contribution: NOMA FL scheduling, power allocation,
adaptive compression, and the FedAvg runtime.

 - channel.py      : cell + fading channel model            (paper §II-A)
 - noma.py         : SIC decoding, SINR, rates              (paper Eq. 4-6)
 - rates.py        : batched SIC rate engine (shared hot path; paper Eq. 2-4)
 - power.py        : MAPEL polyblock power allocation +
                     PowerAllocator (solve/solve_batched)     (paper §III-C)
 - scheduling.py   : SchedulerPolicy protocol + registry; MWIS
                     Algorithm 2 and the online (FL-state-aware)
                     policies                                 (paper §III-A/B)
 - quantization.py : DoReFa adaptive gradient quantization   (paper §II-B)
 - compression.py  : gradient pytree codec over the kernels  (paper Alg. 1)
 - fl.py           : FedAvg over the simulated NOMA cell     (paper §IV)
"""
