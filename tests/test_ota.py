"""OTA analog-aggregation equality grid (core/ota.py + the three drivers).

What must hold, and where each guarantee comes from:

  * ``superpose_tree`` is THE aggregation operator — the batched per-round
    engine, the scanned horizon and the legacy oracle all call the same
    jitted computation, so a fixed delta stack aggregates bit-identically
    no matter which driver asked.
  * noise_std=0, threshold=0 makes the OTA estimate the exact weighted
    FedAvg aggregate (allclose, not bit-equal: the receiver renormalizes
    by the f32 participant weight sum).
  * the Pallas fused scale+superpose+denoise kernel equals the XLA einsum
    oracle, including K=0 (bare noise floor), K=1, and the chunked slab
    path.
  * scanned-horizon and per-round batched OTA runs are END-TO-END
    bit-identical (same traced round body, same host-folded noise keys);
    the legacy oracle agrees to f32 tolerance (its per-device SGD loop
    accumulates in a different order).
  * receiver noise is reproducible from (seed, round) alone and
    decorrelated across rounds; truncation drops sub-threshold channels.
  * FLConfig rejects incoherent OTA combinations with pinned messages.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import channel, fl, ota, power, scheduling
from repro.data import dirichlet_partition, make_mnist_like
from repro.kernels.aggregate import TILE_ELEMS, ota_aggregate_pallas

M = 8
PMAX = 0.01


@pytest.fixture(scope="module")
def world():
    ds = make_mnist_like(num_samples=400, seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.y_train, M, seed=0)
    return ds, cell, shards


def _cfg(**kw):
    base = dict(num_devices=M, group_size=3, num_rounds=3, power_mode="max",
                compression="none", fl_engine="batched", uplink="ota",
                eval_sample=1.0, seed=3)
    base.update(kw)
    return FLConfig(**base)


def _run(world, cfg, **kw):
    ds, cell, shards = world
    return fl.run_federated_learning(ds, shards, cell, cfg, **kw)


def _delta_stack(k=4, sizes=((7, 5), (11,)), seed=0):
    rng = np.random.default_rng(seed)
    return {
        f"leaf{i}": jnp.asarray(
            rng.standard_normal((k, *s)).astype(np.float32))
        for i, s in enumerate(sizes)
    }


# --------------------------------------------------------------------------
# The shared operator: exactness, truncation, noise stream
# --------------------------------------------------------------------------

def test_noiseless_superposition_is_exact_weighted_aggregate():
    deltas = _delta_stack()
    gains = jnp.asarray([1e-6, 2e-6, 5e-7, 3e-6], jnp.float32)
    w = np.asarray([0.1, 0.4, 0.3, 0.2])
    key = jnp.asarray(ota.horizon_keys(0, 1)[0])
    out = ota.superpose_tree(deltas, gains, jnp.asarray(w, jnp.float32), key,
                             pmax=PMAX, noise_std=0.0, threshold=0.0)
    for name, leaf in deltas.items():
        expect = np.einsum(
            "k,k...->...", w / w.sum(), np.asarray(leaf, np.float64))
        np.testing.assert_allclose(
            np.asarray(out[name], np.float64), expect, rtol=2e-6, atol=1e-7)


def test_truncation_drops_subthreshold_channels():
    """threshold=0.4: h=[1, 0.5, 0.1, 0.9]*1e-6 vs hmax=1e-6 keeps devices
    {0, 1, 3}; the estimate must be the renormalized aggregate over the
    survivors only — device 2's update must leave no trace."""
    deltas = _delta_stack()
    gains = jnp.asarray([1e-6, 5e-7, 1e-7, 9e-7], jnp.float32)
    w = np.asarray([0.25, 0.25, 0.25, 0.25])
    key = jnp.asarray(ota.horizon_keys(0, 1)[0])
    out = ota.superpose_tree(deltas, gains, jnp.asarray(w, jnp.float32), key,
                             pmax=PMAX, noise_std=0.0, threshold=0.4)
    keep = np.asarray([0, 1, 3])
    for name, leaf in deltas.items():
        arr = np.asarray(leaf, np.float64)
        expect = np.einsum(
            "k,k...->...", w[keep] / w[keep].sum(), arr[keep])
        np.testing.assert_allclose(
            np.asarray(out[name], np.float64), expect, rtol=2e-6, atol=1e-7)


def test_zero_weight_rows_are_padding():
    """agg_w = 0 marks scan-padding rows: they must not participate even
    with the strongest channel (the T*K > M empty-tail contract)."""
    deltas = _delta_stack()
    gains = jnp.asarray([1e-6, 2e-6, 9e-6, 3e-6], jnp.float32)
    w = np.asarray([0.3, 0.3, 0.0, 0.4])
    key = jnp.asarray(ota.horizon_keys(0, 1)[0])
    out = ota.superpose_tree(deltas, gains, jnp.asarray(w, jnp.float32), key,
                             pmax=PMAX, noise_std=0.0, threshold=0.0)
    keep = np.asarray([0, 1, 3])
    arr = np.asarray(deltas["leaf1"], np.float64)
    expect = np.einsum("k,k...->...", w[keep] / w[keep].sum(), arr[keep])
    np.testing.assert_allclose(
        np.asarray(out["leaf1"], np.float64), expect, rtol=2e-6, atol=1e-7)


def test_empty_round_returns_zero_update():
    deltas = _delta_stack()
    gains = jnp.zeros(4, jnp.float32)
    w = jnp.zeros(4, jnp.float32)
    key = jnp.asarray(ota.horizon_keys(0, 1)[0])
    out = ota.superpose_tree(deltas, gains, w, key,
                             pmax=PMAX, noise_std=1e-3, threshold=0.0)
    for leaf in jax.tree_util.tree_leaves(out):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_noise_stream_deterministic_and_decorrelated():
    deltas = _delta_stack()
    gains = jnp.asarray([1e-6, 2e-6, 5e-7, 3e-6], jnp.float32)
    w = jnp.asarray([0.25, 0.25, 0.25, 0.25], jnp.float32)
    keys = ota.horizon_keys(7, 2)
    kw = dict(pmax=PMAX, noise_std=1e-8, threshold=0.0)
    a = ota.superpose_tree(deltas, gains, w, jnp.asarray(keys[0]), **kw)
    b = ota.superpose_tree(deltas, gains, w, jnp.asarray(keys[0]), **kw)
    c = ota.superpose_tree(deltas, gains, w, jnp.asarray(keys[1]), **kw)
    clean = ota.superpose_tree(deltas, gains, w, jnp.asarray(keys[0]),
                               pmax=PMAX, noise_std=0.0, threshold=0.0)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert any(
        not np.array_equal(np.asarray(la), np.asarray(lc))
        for la, lc in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(c))
    ), "different rounds must draw different receiver noise"
    assert any(
        not np.array_equal(np.asarray(la), np.asarray(lx))
        for la, lx in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(clean))
    ), "noise_std > 0 must actually perturb the aggregate"
    # and the key schedule itself is a pure function of (seed, T)
    np.testing.assert_array_equal(ota.horizon_keys(7, 2),
                                  ota.horizon_keys(7, 5)[:2])


def test_pallas_operator_matches_einsum_operator():
    deltas = _delta_stack()
    gains = jnp.asarray([1e-6, 2e-6, 5e-7, 3e-6], jnp.float32)
    w = jnp.asarray([0.1, 0.4, 0.3, 0.2], jnp.float32)
    key = jnp.asarray(ota.horizon_keys(1, 1)[0])
    kw = dict(pmax=PMAX, noise_std=1e-8, threshold=0.0)
    xla = ota.superpose_tree(deltas, gains, w, key, **kw)
    pal = ota.superpose_tree(deltas, gains, w, key, use_pallas=True, **kw)
    for lx, lp in zip(jax.tree_util.tree_leaves(xla),
                      jax.tree_util.tree_leaves(pal)):
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(lx), rtol=1e-6, atol=1e-9)


# --------------------------------------------------------------------------
# The Pallas kernel vs its einsum oracle (K = 0 / K = 1 / chunked)
# --------------------------------------------------------------------------

def _oracle(flat, coeff, noise):
    return np.einsum(
        "k,kn->n", np.asarray(coeff, np.float64),
        np.asarray(flat, np.float64)) + np.asarray(noise, np.float64)


@pytest.mark.parametrize("k,n", [(4, 1000), (1, 257), (3, TILE_ELEMS + 3)])
def test_ota_kernel_matches_oracle(k, n):
    rng = np.random.default_rng(k * 1000 + n)
    flat = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    coeff = jnp.asarray(rng.dirichlet(np.ones(k)).astype(np.float32))
    noise = jnp.asarray((rng.standard_normal(n) * 1e-3).astype(np.float32))
    out = ota_aggregate_pallas(flat, coeff, noise)
    assert out.dtype == jnp.float32 and out.shape == (n,)
    np.testing.assert_allclose(
        np.asarray(out, np.float64), _oracle(flat, coeff, noise),
        rtol=1e-5, atol=1e-6)


def test_ota_kernel_k0_degenerates_to_noise_floor():
    rng = np.random.default_rng(0)
    noise = jnp.asarray(rng.standard_normal(500).astype(np.float32))
    out = ota_aggregate_pallas(jnp.zeros((0, 500), jnp.float32),
                               jnp.zeros((0,), jnp.float32), noise)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(noise))


def test_ota_kernel_chunked_matches_unchunked():
    """Small chunk_elems forces the lax.map slab path (with the noise strip
    chunked alongside); chunk boundaries must not touch the math."""
    rng = np.random.default_rng(42)
    n = 2 * TILE_ELEMS + 777
    flat = jnp.asarray(rng.standard_normal((3, n)).astype(np.float32))
    coeff = jnp.asarray(rng.dirichlet(np.ones(3)).astype(np.float32))
    noise = jnp.asarray((rng.standard_normal(n) * 1e-3).astype(np.float32))
    whole = ota_aggregate_pallas(flat, coeff, noise)
    chunked = ota_aggregate_pallas(flat, coeff, noise,
                                   chunk_elems=TILE_ELEMS)
    np.testing.assert_array_equal(np.asarray(chunked), np.asarray(whole))
    np.testing.assert_allclose(
        np.asarray(chunked, np.float64), _oracle(flat, coeff, noise),
        rtol=1e-5, atol=1e-6)


def test_ota_kernel_trailing_shape_roundtrip():
    rng = np.random.default_rng(5)
    deltas = jnp.asarray(rng.standard_normal((2, 6, 9)).astype(np.float32))
    coeff = jnp.asarray([0.4, 0.6], jnp.float32)
    noise = jnp.asarray(np.zeros(54, np.float32))
    out = ota_aggregate_pallas(deltas, coeff, noise)
    assert out.shape == (6, 9)
    np.testing.assert_allclose(
        np.asarray(out, np.float64),
        _oracle(deltas.reshape(2, 54), coeff, noise).reshape(6, 9),
        rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Whole-run driver equality
# --------------------------------------------------------------------------

def _assert_same_schedule_and_rates(a, b):
    assert [l.devices for l in a.logs] == [l.devices for l in b.logs]
    for la, lb in zip(a.logs, b.logs):
        np.testing.assert_array_equal(la.bits, lb.bits)
        np.testing.assert_array_equal(la.rates, lb.rates)
    np.testing.assert_array_equal(a.times(), b.times())


def test_scan_equals_per_round_bit_identical(world):
    ds, cell, shards = world
    cfg = _cfg(ota_noise=1e-9, horizon="scan")
    scanned = fl.run_horizon_scanned(ds, shards, cell, cfg)
    per_round = _run(world, dataclasses.replace(cfg, horizon="per-round"))
    _assert_same_schedule_and_rates(scanned, per_round)
    np.testing.assert_array_equal(scanned.accuracies(),
                                  per_round.accuracies())
    for x, y in zip(jax.tree_util.tree_leaves(scanned.final_params),
                    jax.tree_util.tree_leaves(per_round.final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_legacy_oracle_agrees_with_batched_engine(world):
    cfg_b = _cfg(ota_noise=1e-9)
    cfg_l = dataclasses.replace(cfg_b, fl_engine="legacy")
    rb = _run(world, cfg_b)
    rl = _run(world, cfg_l)
    _assert_same_schedule_and_rates(rb, rl)
    np.testing.assert_allclose(rb.accuracies(), rl.accuracies(), atol=0.051)
    for x, y in zip(jax.tree_util.tree_leaves(rb.final_params),
                    jax.tree_util.tree_leaves(rl.final_params)):
        d = np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))
        assert d.mean() < 1e-6 and d.max() < 2e-2


def test_noiseless_ota_run_matches_digital_uncompressed(world):
    """noise_std=0, threshold=0: the analog sum IS the weighted aggregate,
    so the whole run must track the digital uncompressed NOMA run — same
    schedule (both precomputed from the same channel draws), near-identical
    params (the OTA receiver renormalizes by the f32 weight sum)."""
    ro = _run(world, _cfg(ota_noise=0.0))
    rn = _run(world, _cfg(uplink="noma"))
    assert [l.devices for l in ro.logs] == [l.devices for l in rn.logs]
    np.testing.assert_allclose(ro.accuracies(), rn.accuracies(), atol=0.051)
    for x, y in zip(jax.tree_util.tree_leaves(ro.final_params),
                    jax.tree_util.tree_leaves(rn.final_params)):
        d = np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))
        assert d.mean() < 1e-6 and d.max() < 2e-2


def test_ota_round_charges_one_shared_slot(world):
    """OTA airtime accounting mirrors NOMA's: one shared uplink slot per
    round regardless of group size (TDMA charges one slot per device)."""
    cell = world[1]
    ro = _run(world, _cfg(ota_noise=1e-9))
    rt = _run(world, _cfg(uplink="tdma"))
    # same gains/scheduler/powers -> same schedule; only the airtime differs
    assert [l.devices for l in ro.logs] == [l.devices for l in rt.logs]
    dt_o = np.diff(np.concatenate([[0.0], ro.times()]))
    dt_t = np.diff(np.concatenate([[0.0], rt.times()]))
    # same downlink cost both runs; uplink slot_seconds vs K*slot_seconds
    np.testing.assert_allclose(
        dt_t - dt_o,
        [(len(l.devices) - 1) * cell.slot_seconds for l in rt.logs],
        rtol=1e-6)


def test_vmapped_sweep_row_equals_scanned_run(world):
    cfg = _cfg(ota_noise=1e-9, horizon="scan")
    ds, cell, shards = world
    sweep = fl.run_horizon_vmapped(ds, shards, cell, cfg, seeds=[3, 4])
    solo = fl.run_horizon_scanned(ds, shards, cell, cfg)
    np.testing.assert_array_equal(sweep[0].accuracies(), solo.accuracies())
    assert not np.array_equal(sweep[1].accuracies(), solo.accuracies())


# --------------------------------------------------------------------------
# Config validation: pinned messages
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kw,frag", [
    (dict(uplink="carrier-pigeon"), "unknown uplink"),
    (dict(uplink="ota", compression="adaptive"),
     "requires compression='none'"),
    # topk needs compression='adaptive' + batched to get past FLConfig's own
    # coherence checks and reach the check_uplink pinned message
    (dict(uplink="ota", compression="adaptive", topk=0.5,
          fl_engine="batched"), "cannot apply top-k sparsification"),
    (dict(uplink="ota", compression="none", power_mode="mapel"),
     "cannot use power_mode='mapel'"),
    (dict(uplink="noma", power_mode="ota-align"),
     "requires uplink='ota'"),
    (dict(uplink="ota", compression="none", power_mode="max",
          ota_noise=-1.0), "ota_noise must be >= 0"),
    (dict(uplink="ota", compression="none", power_mode="max",
          ota_threshold=1.0), "ota_threshold must be in"),
])
def test_flconfig_rejects_incoherent_ota_combos(kw, frag):
    base = dict(num_devices=M, group_size=3, num_rounds=3)
    with pytest.raises(ValueError, match=frag):
        FLConfig(**base, **kw)


def test_drivers_validate_call_site_uplink_override(world):
    """cfg may be coherent while the uplink= call argument is not — the
    drivers re-run check_uplink on the resolved value."""
    cfg = FLConfig(num_devices=M, group_size=3, num_rounds=2,
                   compression="adaptive", power_mode="max")
    with pytest.raises(ValueError, match="requires compression='none'"):
        _run(world, cfg, uplink="ota")


# --------------------------------------------------------------------------
# matching-pursuit policy + ota-align powers
# --------------------------------------------------------------------------

def test_matching_pursuit_registered_and_online():
    assert "matching-pursuit" in scheduling.available_policies()
    pol = scheduling.get_policy("matching-pursuit")
    assert pol.online and not pol.respects_c1 and pol.needs_norms


def test_matching_pursuit_noiseless_is_topk_by_weighted_energy():
    """lambda = 0 (ota_noise = 0) kills the channel penalty: round 0 (all
    norm estimates equal) must admit the K largest FedAvg weights."""
    rng = np.random.default_rng(1)
    gains = np.abs(rng.normal(1e-6, 5e-7, (1, 6))) + 1e-8
    w = np.asarray([0.05, 0.3, 0.1, 0.25, 0.2, 0.1])
    pol = scheduling.get_policy("matching-pursuit")
    cfg = scheduling.PolicyConfig(group_size=3, pmax=PMAX, ota_noise=0.0)
    state = pol.init_state(gains, w, cfg)
    group, _ = pol.select_round(0, state, scheduling.Observation.initial(6))
    assert set(group) == {1, 3, 4}


def test_matching_pursuit_penalizes_weak_channels():
    """With receiver noise, a heavy device behind a dead channel must lose
    to lighter devices with clean channels (the channel-inversion noise
    referral 1/h^2 outweighs its energy contribution)."""
    gains = np.asarray([[1e-9, 1e-6, 1e-6, 1e-6]])
    w = np.asarray([0.4, 0.2, 0.2, 0.2])
    pol = scheduling.get_policy("matching-pursuit")
    cfg = scheduling.PolicyConfig(group_size=2, pmax=PMAX, ota_noise=1e-8)
    state = pol.init_state(gains, w, cfg)
    group, _ = pol.select_round(0, state, scheduling.Observation.initial(4))
    assert 0 not in group and len(group) == 2


def test_matching_pursuit_live_ota_run(world):
    cfg = _cfg(scheduler="matching-pursuit", ota_noise=1e-9)
    res = _run(world, cfg)
    assert all(0 < len(l.devices) <= 3 for l in res.logs)
    assert len(res.accuracies()) == 3


def test_ota_align_powers_properties():
    gains = np.asarray([1e-6, 2e-6, 5e-7, 0.0])
    w = np.asarray([0.3, 0.2, 0.4, 0.1])
    p = power.ota_align_powers(gains, w, PMAX)
    live = slice(0, 3)
    # the binding device transmits at exactly pmax...
    assert p.max() == pytest.approx(PMAX)
    assert np.all(p <= PMAX * (1 + 1e-12))
    # ...alignment: p_k h_k^2 / w_k^2 = eta constant across live devices
    eta = p[live] * gains[live] ** 2 / w[live] ** 2
    np.testing.assert_allclose(eta, eta[0], rtol=1e-9)
    # dead channel transmits nothing
    assert p[3] == 0.0
    # allocator front door
    alloc = power.make_power_allocator("ota-align", PMAX, 1e-13)
    np.testing.assert_array_equal(alloc(gains, w), p)
    batched = alloc.batched(np.stack([gains, gains]), np.stack([w, w]))
    np.testing.assert_array_equal(batched[0], p)
    np.testing.assert_array_equal(batched[1], p)
