"""SchedulerPolicy protocol + registry (the scheduling API redesign).

Covers: every registered policy end-to-end on a tiny instance, bit-identical
legacy equivalence (registry name vs pre-refactor function, both backends),
the random policy's self-contained RNG (seed + 17 hoist), the proportional
fair weighted-rate ranking fix, online policy scoring semantics, and the
FLConfig construction-time validation against the registries.
"""
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import power as power_lib
from repro.core import scheduling

NOISE = 1.6e-14
PMAX = 0.01

LEGACY_NAMES = [
    "lazy-gwmin", "literal-gwmin", "random", "round-robin", "proportional-fair",
]


def _instance(m, t, seed):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    return gains, w


def _pcfg(k, **kw):
    kw.setdefault("pmax", PMAX)
    kw.setdefault("noise_power", NOISE)
    return scheduling.PolicyConfig(group_size=k, **kw)


def _legacy(name, gains, w, k, *, power_mode="max", seed=0, backend="numpy"):
    """The pre-refactor call paths (including fl.make_schedule's seed+17)."""
    kw = dict(power_mode=power_mode, pmax=PMAX, noise_power=NOISE)
    if name == "lazy-gwmin":
        return scheduling.lazy_greedy_schedule(gains, w, k, backend=backend, **kw)
    if name == "literal-gwmin":
        return scheduling.literal_graph_schedule(gains, w, k, **kw)
    if name == "random":
        rng = np.random.default_rng(seed + 17)
        return scheduling.random_schedule(rng, gains, w, k, **kw)
    if name == "round-robin":
        return scheduling.round_robin_schedule(gains, w, k, **kw)
    if name == "proportional-fair":
        return scheduling.proportional_fair_schedule(gains, w, k, **kw)
    raise ValueError(name)


def _assert_bit_identical(a, b):
    assert a.rounds == b.rounds
    for pa, pb in zip(a.powers, b.powers):
        np.testing.assert_array_equal(pa, pb)
    for ra, rb in zip(a.rates, b.rates):
        np.testing.assert_array_equal(ra, rb)
    assert a.weighted_sum_rate == b.weighted_sum_rate
    assert a.method == b.method


# --------------------------------------------------------------------------
# Registry basics
# --------------------------------------------------------------------------

def test_registry_contains_all_policies():
    names = scheduling.available_policies()
    for name in LEGACY_NAMES + ["update-aware", "age-fair"]:
        assert name in names


def test_get_policy_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown scheduler"):
        scheduling.get_policy("mystery-policy")


def test_register_policy_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        scheduling.register_policy("random")(type("Dup", (), {}))


def test_every_registered_policy_end_to_end():
    """Every policy runs on the tiny (M=9, K=3, T=4) instance — a T*K > M
    horizon, so precomputed policies emit tails and online ones revisit —
    and returns a Schedule passing validate."""
    gains, w = _instance(9, 4, seed=3)
    for name in scheduling.available_policies():
        policy = scheduling.get_policy(name)
        sched = scheduling.build_schedule(policy, gains, w, _pcfg(3))
        assert isinstance(sched, scheduling.Schedule)
        assert len(sched.rounds) == 4
        assert sched.method == name
        assert sched.validate(9, 3, allow_revisits=not policy.respects_c1)
        if policy.online:
            # online policies never leave a round empty
            assert all(len(g) == 3 for g in sched.rounds)


# --------------------------------------------------------------------------
# Legacy equivalence: registry name == pre-refactor function, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", LEGACY_NAMES)
@pytest.mark.parametrize("m,t,k", [(9, 3, 2), (8, 2, 3)])
def test_legacy_names_bit_identical(name, m, t, k):
    gains, w = _instance(m, t, seed=11)
    sched = scheduling.build_schedule(
        scheduling.get_policy(name), gains, w, _pcfg(k)
    )
    _assert_bit_identical(sched, _legacy(name, gains, w, k))


@pytest.mark.parametrize("name", ["lazy-gwmin", "random", "proportional-fair"])
def test_legacy_names_bit_identical_with_mapel(name):
    gains, w = _instance(8, 3, seed=5)
    sched = scheduling.build_schedule(
        scheduling.get_policy(name), gains, w, _pcfg(2, power_mode="mapel")
    )
    _assert_bit_identical(sched, _legacy(name, gains, w, 2, power_mode="mapel"))


def test_lazy_gwmin_policy_jax_backend_bit_identical():
    pytest.importorskip("jax")
    gains, w = _instance(12, 3, seed=7)
    sched = scheduling.build_schedule(
        scheduling.get_policy("lazy-gwmin"), gains, w, _pcfg(3, backend="jax")
    )
    _assert_bit_identical(sched, _legacy("lazy-gwmin", gains, w, 3, backend="jax"))
    _assert_bit_identical(sched, _legacy("lazy-gwmin", gains, w, 3))


# --------------------------------------------------------------------------
# Random policy: schedule reproducible from (inputs, PolicyConfig) alone
# --------------------------------------------------------------------------

def test_random_policy_owns_its_rng():
    """The seed+17 offset lives in RandomPolicy.init_state now, not in
    fl.make_schedule — same cfg, same schedule, no FL runtime involved."""
    gains, w = _instance(10, 3, seed=0)
    a = scheduling.build_schedule(
        scheduling.get_policy("random"), gains, w, _pcfg(3, seed=42)
    )
    b = scheduling.build_schedule(
        scheduling.get_policy("random"), gains, w, _pcfg(3, seed=42)
    )
    assert a.rounds == b.rounds
    # and the plan is exactly the documented derivation
    perm = np.random.default_rng(42 + scheduling.RandomPolicy.SEED_OFFSET
                                 ).permutation(10)
    assert a.rounds == [tuple(perm[t * 3:(t + 1) * 3].tolist()) for t in range(3)]
    c = scheduling.build_schedule(
        scheduling.get_policy("random"), gains, w, _pcfg(3, seed=43)
    )
    assert c.rounds != a.rounds


# --------------------------------------------------------------------------
# Proportional fair: rank by w_k R_k, not raw gain (failing before the fix)
# --------------------------------------------------------------------------

def test_proportional_fair_ranks_by_weighted_rate():
    """Device 0 has the strongest channel but negligible FedAvg weight; the
    MWIS objective (w_k R_k) prefers device 1.  The seed's raw-gain ranking
    picked device 0 — that behaviour now requires by_gain=True."""
    gains = np.array([[3e-6, 1e-6]])
    w = np.array([0.01, 0.99])
    fixed = scheduling.proportional_fair_schedule(gains, w, 1, noise_power=NOISE)
    legacy = scheduling.proportional_fair_schedule(
        gains, w, 1, noise_power=NOISE, by_gain=True
    )
    assert legacy.rounds == [(0,)]          # raw gain: strongest channel wins
    assert fixed.rounds == [(1,)]           # weighted solo rate: w_k R_k wins
    assert fixed.weighted_sum_rate > legacy.weighted_sum_rate


def test_proportional_fair_by_gain_through_registry():
    gains, w = _instance(10, 3, seed=9)
    via_registry = scheduling.build_schedule(
        scheduling.get_policy("proportional-fair", by_gain=True),
        gains, w, _pcfg(3),
    )
    direct = scheduling.proportional_fair_schedule(
        gains, w, 3, noise_power=NOISE, by_gain=True
    )
    _assert_bit_identical(via_registry, direct)


# --------------------------------------------------------------------------
# Online policies: scoring semantics
# --------------------------------------------------------------------------

def test_update_aware_round0_is_best_channel():
    """With no observations every device carries the same default norm, so
    round 0 reduces to top-K by weighted solo rate."""
    gains, w = _instance(8, 2, seed=13)
    policy = scheduling.get_policy("update-aware")
    cfg = _pcfg(3)
    state = policy.init_state(gains, w, cfg)
    obs = scheduling.Observation.initial(8)
    group, _ = policy.select_round(0, state, obs)
    solo = w * np.log2(1.0 + PMAX * gains[0] ** 2 / NOISE)
    expect = tuple(np.argsort(-solo, kind="stable")[:3].tolist())
    assert group == expect


def test_update_aware_prefers_large_update_norms():
    """A device whose last update was huge outranks a slightly-faster device
    whose update was tiny — the ||dW|| * rate product at work."""
    m = 4
    gains = np.full((2, m), 1e-6)
    w = np.full(m, 1.0 / m)                 # equal rates, equal weights
    policy = scheduling.get_policy("update-aware")
    state = policy.init_state(gains, w, _pcfg(2))
    obs = scheduling.Observation.initial(m)
    obs = obs.record_round(0, (0, 1, 2, 3), np.ones(m),
                           update_norms_k=[0.1, 5.0, 0.2, 4.0])
    group, _ = policy.select_round(1, state, obs)
    assert set(group) == {1, 3}


def test_age_fair_revisits_and_never_starves():
    """Over a long horizon every device gets scheduled: the (1 + age) boost
    eventually dominates any channel gap."""
    m, t, k = 6, 12, 2
    gains, w = _instance(m, t, seed=17)
    sched = scheduling.build_schedule(
        scheduling.get_policy("age-fair"), gains, w, _pcfg(k)
    )
    assert sched.scheduled_devices() == set(range(m))
    assert all(len(g) == k for g in sched.rounds)   # no empty tail rounds
    counts = np.zeros(m, dtype=int)
    for g in sched.rounds:
        counts[list(g)] += 1
    assert counts.max() > 1                          # revisits happened (C1 off)


def test_observation_record_round_is_functional():
    obs = scheduling.Observation.initial(5)
    new = obs.record_round(3, (1, 4), [2.0, 3.0], update_norms_k=[0.5, 0.7])
    assert obs.participation.sum() == 0              # original untouched
    assert new.participation[1] == 1 and new.last_round[4] == 3
    assert new.realized_rates[4] == 3.0 and new.update_norms[1] == 0.5
    assert new.last_round[0] == -1


# --------------------------------------------------------------------------
# FLConfig: construction-time validation against the registries
# --------------------------------------------------------------------------

def test_flconfig_rejects_bad_values_at_construction():
    with pytest.raises(ValueError, match="scheduler"):
        FLConfig(scheduler="mystery-policy")
    with pytest.raises(ValueError, match="power_mode"):
        FLConfig(power_mode="psycho")
    with pytest.raises(ValueError, match="group_size"):
        FLConfig(num_devices=2, group_size=3)
    with pytest.raises(ValueError, match="num_rounds"):
        FLConfig(num_rounds=0)
    with pytest.raises(ValueError, match="scheduler_backend"):
        FLConfig(scheduler_backend="tpu-v9")


def test_flconfig_accepts_every_registered_policy():
    for name in scheduling.available_policies():
        cfg = FLConfig(scheduler=name)
        assert cfg.scheduler == name
    for mode in power_lib.POWER_MODES:
        # ota-align is the analog uplink's allocator and rejects digital
        # configs by design (ota.check_uplink), so give it its home combo
        kw = (
            {"uplink": "ota", "compression": "none"}
            if mode == "ota-align" else {}
        )
        FLConfig(power_mode=mode, **kw)


def test_live_mode_rejects_invalid_policy_groups():
    """The FL loop validates what online policies hand back: oversized,
    duplicated, or out-of-range groups raise instead of silently indexing
    the wrong shard (negative ids would wrap through numpy indexing)."""
    from repro.core import channel, fl
    from repro.data import dirichlet_partition, make_mnist_like

    @scheduling.register_policy("test-rogue")
    class RoguePolicy(scheduling._ScoreTopKPolicy):
        def select_round(self, t, state, obs):
            return (-1, 0), state

    try:
        ds = make_mnist_like(num_samples=200, seed=0)
        cell = channel.CellConfig(num_devices=4)
        shards = dirichlet_partition(ds.y_train, 4, seed=0)
        cfg = FLConfig(num_devices=4, group_size=2, num_rounds=2,
                       scheduler="test-rogue", power_mode="max", seed=0)
        with pytest.raises(ValueError, match="invalid round-0 group"):
            fl.run_federated_learning(ds, shards, cell, cfg)
    finally:
        scheduling._REGISTRY.pop("test-rogue", None)
