"""Granite-34B-Code: llama-arch dense with MQA (kv=1) [arXiv:2405.04324]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    source="arXiv:2405.04324",
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=1,
    d_ff=512, vocab_size=512, head_dim=64,
    source="reduced granite family",
)
