"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels are validated against
these with assert_allclose across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_codes_ref(x: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    """DoReFa integer codes: round(a * clip(x/scale, -1, 1)), a = 2^b - 1."""
    a = float(2 ** int(bits) - 1)
    xn = jnp.clip(x.astype(jnp.float32) / scale, -1.0, 1.0)
    return jnp.round(a * xn).astype(jnp.int32)


def dequantize_codes_ref(codes: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    a = float(2 ** int(bits) - 1)
    return codes.astype(jnp.float32) / a * scale


def quantize_dequantize_ref(x: jnp.ndarray, bits: int, scale) -> jnp.ndarray:
    """Fused q->dq (the uplink simulation used inside train steps)."""
    return dequantize_codes_ref(quantize_codes_ref(x, bits, scale), bits, scale).astype(
        x.dtype
    )


def weighted_aggregate_ref(
    codes: jnp.ndarray,    # (K, N) int32
    scales: jnp.ndarray,   # (K,)
    weights: jnp.ndarray,  # (K,)
    bits: int,
) -> jnp.ndarray:
    """Server-side fused dequant + weighted sum:  sum_k w_k dq(codes_k)."""
    a = float(2 ** int(bits) - 1)
    deq = codes.astype(jnp.float32) / a * scales[:, None]
    return jnp.sum(weights[:, None] * deq, axis=0)


def flash_decode_ref(q, k, v, valid_len):
    """One-token GQA decode oracle. q: (B,Hkv,G,D); k,v: (B,S,Hkv,D)."""
    d = q.shape[-1]
    s = jnp.einsum(
        "bhgd,bshd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(d)
    pos = jnp.arange(k.shape[1])
    s = jnp.where(pos[None, None, None, :] < valid_len, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)


def sic_weighted_rates_ref(powers_vk, gains_vk, weights_vk, noise_power):
    """Batched SIC weighted sum rate oracle: (V, K) -> (V,).

    Delegates to ``repro.core.rates_jax`` — the single jnp SIC formulation
    shared with the device-resident MWIS greedy — at the kernels' float32
    working precision.  Decode order is descending receive power with ties
    to the lower input index (stable argsort), the same order as the numpy
    engine and the Pallas comparison-matrix kernel; the interference tail is
    the shifted suffix sum, bit-compatible with ``repro.core.rates``.
    """
    from repro.core import rates_jax

    return rates_jax.batched_weighted_rates(
        jnp.asarray(powers_vk, jnp.float32),
        jnp.asarray(gains_vk, jnp.float32),
        jnp.asarray(weights_vk, jnp.float32),
        noise_power,
    )
