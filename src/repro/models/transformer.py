"""Dense decoder-only transformer (llama/qwen/granite/mistral families).

Supports GQA/MQA (with KV-head replication for sharding), qk-norm (qwen3),
QKV bias (qwen2), sliding-window attention (mixtral), block-local attention
(llama4 long-context), RoPE, SwiGLU MLP. Layers run under lax.scan with
stacked params (O(1) HLO in depth).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import ParamSpec, stacked


def block_schema(cfg, *, shards: int = 16):
    return {
        "ln1": L.rmsnorm_schema(cfg.d_model),
        "attn": L.attention_schema(cfg, shards=shards),
        "ln2": L.rmsnorm_schema(cfg.d_model),
        "mlp": L.mlp_schema(cfg.d_model, cfg.d_ff),
    }


def schema(cfg, *, shards: int = 16):
    return {
        "embed": L.embedding_schema(cfg.padded_vocab, cfg.d_model, tie=cfg.tie_embeddings),
        "layers": stacked(block_schema(cfg, shards=shards), cfg.num_layers),
        "ln_f": L.rmsnorm_schema(cfg.d_model),
    }


def mask_spec(cfg) -> L.AttnMaskSpec:
    return L.AttnMaskSpec(
        causal=True, window=cfg.sliding_window, block_local=cfg.attention_chunk
    )


def transformer_block(p, x, cfg, *, mspec, positions, cache, kv_chunk):
    h, new_cache = L.attention_block(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
        mask_spec=mspec, positions=positions, cache=cache, kv_chunk=kv_chunk,
    )
    x = L.constrain(x + h, "residual")
    x = x + L.mlp_block(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return L.constrain(x, "residual"), new_cache


def forward(
    params,
    tokens: jax.Array,                  # (B, S)
    cfg,
    *,
    caches: Optional[dict] = None,      # stacked per-layer cache pytree
    positions: Optional[jax.Array] = None,
    kv_chunk: int = 1024,
    remat: bool = True,
    unroll: bool = False,
):
    """Returns (logits (B,S,V), new_caches)."""
    x = L.embed(params["embed"], tokens)
    mspec = mask_spec(cfg)
    if positions is None and caches is not None:
        positions = caches["len"][0] + jnp.arange(tokens.shape[1])[None, :]

    def body(x, xs):
        p_layer, cache = xs
        y, new_cache = transformer_block(
            p_layer, x, cfg, mspec=mspec, positions=positions,
            cache=cache, kv_chunk=kv_chunk,
        )
        return y, new_cache

    fn = jax.checkpoint(body) if (remat and caches is None) else body
    x, new_caches = jax.lax.scan(fn, x, (params["layers"], caches), unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, tie=cfg.tie_embeddings)
    return logits, new_caches


def loss_fn(params, batch, cfg, **kw):
    logits, _ = forward(params, batch["tokens"], cfg, **kw)
    return L.cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int, *, shards: int = 16):
    """Stacked (per-layer) KV cache for decode."""
    one = L.init_attn_cache(cfg, batch, max_len, shards=shards)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers, *x.shape)), one
    )


def decode_step(params, caches, tokens, cfg, *, kv_chunk: int = 4096,
                unroll: bool = False):
    """One-token decode: tokens (B, 1). Returns (logits (B,1,V), caches)."""
    logits, new_caches = forward(
        params, tokens, cfg, caches=caches, kv_chunk=kv_chunk, remat=False,
        unroll=unroll,
    )
    return logits, new_caches
