"""DoReFa quantization (paper Eq. 7) properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.core import quantization as q


def test_levels():
    assert float(q.dorefa_levels(1)) == 1.0
    assert float(q.dorefa_levels(8)) == 255.0


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_quantize_error_bound(bits, seed):
    """|x - q(x)| <= scale / (2 * (2^b - 1)) for x in [-scale, scale]."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 0.5
    y = q.quantize(x, bits)
    scale = float(jnp.max(jnp.abs(x)))
    bound = scale / (2 * (2**bits - 1)) + 1e-6
    assert float(jnp.max(jnp.abs(x - y))) <= bound


def test_quantize_paper_exact_matches_eq7():
    """With scale=1 the codec is exactly (1/a) round(a*pi)."""
    x = jnp.asarray([-1.0, -0.51, 0.0, 0.26, 0.74, 1.0])
    for b in (1, 2, 3):
        a = 2**b - 1
        np.testing.assert_allclose(
            np.asarray(q.quantize(x, b, scale=1.0)),
            np.round(a * np.asarray(x)) / a,
            atol=1e-7,
        )


def test_quantize_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    y = q.quantize(x, 5)
    # quantizing an already-quantized tensor with the same scale is identity
    z = q.quantize(y, 5, scale=float(jnp.max(jnp.abs(x))))
    np.testing.assert_allclose(np.asarray(y), np.asarray(z), atol=1e-6)


def test_bits_32_passthrough():
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    np.testing.assert_array_equal(np.asarray(q.quantize(x, 32)), np.asarray(x))


def test_adaptive_bits_formula():
    # r = max(I/c, 1); b = floor(32/r) clamped to [1, 32]  (paper §II-B)
    assert int(q.adaptive_bits(3200.0, 1600.0)) == 16
    assert int(q.adaptive_bits(3200.0, 3200.0)) == 32
    assert int(q.adaptive_bits(3200.0, 1e12)) == 32
    assert int(q.adaptive_bits(3200.0, 10.0)) == 1  # clamp at 1 bit
    assert int(q.adaptive_bits(3200.0, 800.0)) == 8


@settings(max_examples=30, deadline=None)
@given(st.floats(1e3, 1e9), st.floats(1.0, 1e9))
def test_adaptive_bits_monotone_in_budget(payload, budget):
    b1 = int(q.adaptive_bits(payload, budget))
    b2 = int(q.adaptive_bits(payload, budget * 2))
    assert 1 <= b1 <= 32 and b1 <= b2


def test_quantize_tree_structure_preserved():
    tree = {"a": jnp.ones((4, 4)), "b": [jnp.zeros(3), jnp.full((2,), 0.3)]}
    out = q.quantize_tree(tree, 4)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(tree)


def test_error_decreases_with_bits():
    x = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    errs = [float(q.quantization_error(x, b)) for b in (1, 2, 4, 8, 16)]
    assert all(e1 > e2 for e1, e2 in zip(errs, errs[1:]))
