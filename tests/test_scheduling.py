"""Scheduling graph + Algorithm 2 (paper §III-A/B)."""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.core import scheduling

NOISE = 1.6e-14


def _instance(m, t, seed):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    return gains, w


def test_graph_structure_matches_paper_example():
    """Paper Fig. 4: M=4, K=1, T=2 -> 8 vertices; same-round and same-device
    vertices are connected."""
    gains, w = _instance(4, 2, 0)
    g = scheduling.build_scheduling_graph(
        gains, w, 1, lambda gg, ww: np.full(len(gg), 0.01), NOISE
    )
    assert len(g.vertices) == 8
    idx = {v: i for i, v in enumerate(g.vertices)}
    v_11 = idx[((0,), 0)]  # device 0 at round 0  (paper's "(1)1")
    # connected to the 3 other round-0 vertices and to itself-in-round-1
    neigh = {g.vertices[j] for j in g.adjacency[v_11]}
    assert ((1,), 0) in neigh and ((2,), 0) in neigh and ((3,), 0) in neigh
    assert ((0,), 1) in neigh
    assert ((2,), 1) not in neigh  # independent: schedulable together


def test_gwmin_output_is_independent_set():
    gains, w = _instance(5, 2, 1)
    g = scheduling.build_scheduling_graph(
        gains, w, 2, lambda gg, ww: np.full(len(gg), 0.01), NOISE
    )
    chosen = scheduling.gwmin_mwis(g)
    for a, b in itertools.combinations(chosen, 2):
        assert b not in g.adjacency[a]


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 7), st.integers(1, 2), st.integers(1, 2), st.integers(0, 9999))
def test_lazy_equals_literal(m, k, t, seed):
    """The lazy column-generation greedy is Algorithm 2 without the graph
    (DESIGN.md §6.3)."""
    if m < k * t:
        return
    gains, w = _instance(m, t, seed)
    lit = scheduling.literal_graph_schedule(gains, w, k, noise_power=NOISE)
    lazy = scheduling.lazy_greedy_schedule(gains, w, k, noise_power=NOISE)
    assert lit.rounds == lazy.rounds
    assert lit.weighted_sum_rate == pytest.approx(lazy.weighted_sum_rate, rel=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 9999))
def test_greedy_within_brute_force(seed):
    gains, w = _instance(5, 2, seed)
    greedy = scheduling.lazy_greedy_schedule(gains, w, 2, noise_power=NOISE)
    best = scheduling.brute_force_schedule(gains, w, 2, noise_power=NOISE)
    assert greedy.weighted_sum_rate <= best.weighted_sum_rate + 1e-9
    # GWMIN greedy on interval-structured conflict graphs stays within a
    # modest factor in practice; guard against catastrophic regressions.
    assert greedy.weighted_sum_rate >= 0.5 * best.weighted_sum_rate


def test_all_schedulers_respect_constraints():
    gains, w = _instance(12, 3, 3)
    rng = np.random.default_rng(0)
    for sched in [
        scheduling.lazy_greedy_schedule(gains, w, 3, noise_power=NOISE),
        scheduling.random_schedule(rng, gains, w, 3, noise_power=NOISE),
        scheduling.round_robin_schedule(gains, w, 3, noise_power=NOISE),
        scheduling.proportional_fair_schedule(gains, w, 3, noise_power=NOISE),
    ]:
        assert sched.validate(12, 3)
        assert len(sched.rounds) == 3


def test_round_robin_more_rounds_than_devices():
    """Regression: T*K > M used to emit device ids >= M and crash the gains
    gather; tail rounds must instead get the (possibly empty) leftovers."""
    gains, w = _instance(5, 4, 7)  # M=5 devices, T=4 rounds, K=2 -> T*K > M
    sched = scheduling.round_robin_schedule(gains, w, 2, noise_power=NOISE)
    assert sched.validate(5, 2)
    assert sched.rounds == [(0, 1), (2, 3), (4,), ()]
    assert sched.scheduled_devices() == set(range(5))


def test_greedy_beats_random_on_average():
    vals_g, vals_r = [], []
    for seed in range(8):
        gains, w = _instance(20, 3, seed)
        rng = np.random.default_rng(seed)
        vals_g.append(
            scheduling.lazy_greedy_schedule(gains, w, 2, noise_power=NOISE).weighted_sum_rate
        )
        vals_r.append(
            scheduling.random_schedule(rng, gains, w, 2, noise_power=NOISE).weighted_sum_rate
        )
    assert np.mean(vals_g) > np.mean(vals_r)


def test_mapel_power_mode_improves_weighted_rate():
    gains, w = _instance(8, 2, 11)
    base = scheduling.lazy_greedy_schedule(
        gains, w, 2, power_mode="max", noise_power=NOISE
    )
    opt = scheduling.lazy_greedy_schedule(
        gains, w, 2, power_mode="mapel", noise_power=NOISE
    )
    assert opt.weighted_sum_rate >= base.weighted_sum_rate - 1e-6
