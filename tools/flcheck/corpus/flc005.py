"""FLC005 corpus: catastrophic cancellation — log(1+x) / 1-exp(x).

The PR 5 bug: f32 ``log(1 + x)`` underflowed for tiny downlink SNR and
poisoned the Fig. 5 time axis; ``log1p`` / ``expm1`` keep full precision
for small |x|.  ``log2(1 + SINR)`` is deliberately NOT matched — that is
the Shannon rate formula, bit-pinned across the scheduler tests.  Never
executed — parsed only.
"""
import jax.numpy as jnp


def bad_log_one_plus(snr):
    return jnp.log(1.0 + snr)  # expect: FLC005


def bad_one_minus_exp(t):
    return 1.0 - jnp.exp(-t)  # expect: FLC005


def good_log1p_expm1(snr, t):
    return jnp.log1p(snr) - jnp.expm1(-t)


def good_shannon_rate(sinr):
    # base-2 log of (1 + SINR) is the rate formula, not a precision bug
    return jnp.log2(1.0 + sinr)


def good_offset_not_one(x):
    return jnp.log(2.0 + x)
