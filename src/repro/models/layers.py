"""Shared neural building blocks (pure functions over param dicts).

Conventions:
  * activations layout (B, S, ...) with heads as (B, S, H, D) — MaxText-style.
  * all matmul params fp32, compute cast to bf16, reductions/softmax in fp32.
  * attention never materializes (S, S): online-softmax over KV chunks
    (lax.scan), which is the TPU-native flash formulation at the XLA level
    (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.interpreters import batching

from repro.models.params import ParamSpec

COMPUTE_DTYPE = jnp.bfloat16

# --------------------------------------------------------------------------
# Activation-sharding hook (set by the launcher; identity on single device)
#
# Without explicit constraints the SPMD partitioner's strategy for the
# residual stream is underconstrained and degrades with depth (measured:
# 10 GiB -> 115 GiB of fp32 activation all-reduce going from 2 to 4 layers
# on llama-3.2-vision/prefill_32k — EXPERIMENTS.md §Perf pair B). The
# launcher installs a hook that pins: residual (B,S,D) -> (batch, None,
# None); heads (B,S,H,K) -> (batch, None, tensor, None).
# --------------------------------------------------------------------------

_ACT_SHARDING_HOOK = None


def set_activation_sharding(hook):
    """hook: callable(x, kind) -> x, kind in {"residual", "heads"}."""
    global _ACT_SHARDING_HOOK
    _ACT_SHARDING_HOOK = hook


def constrain(x, kind: str):
    if _ACT_SHARDING_HOOK is None:
        return x
    return _ACT_SHARDING_HOOK(x, kind)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm_schema(d: int):
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) or (S,). Rotates pairs (even, odd)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * freq[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Chunked (online-softmax) attention
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnMaskSpec:
    causal: bool = True
    window: Optional[int] = None        # sliding-window attention (mixtral)
    block_local: Optional[int] = None   # llama4 chunked-local attention


def _mask_block(q_pos, k_pos, spec: AttnMaskSpec):
    """(Sq, Sk) bool mask block from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if spec.causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if spec.window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < spec.window
    if spec.block_local is not None:
        m &= (q_pos[:, None] // spec.block_local) == (k_pos[None, :] // spec.block_local)
    return m


def chunked_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    *,
    mask_spec: AttnMaskSpec,
    q_offset: int | jax.Array = 0,
    kv_chunk: int = 1024,
    kv_valid_len: Optional[jax.Array] = None,  # decode: #valid cache slots
) -> jax.Array:
    """Grouped-query online-softmax attention, O(Sq * chunk) memory.

    GQA is computed grouped — Q reshaped to (B, Sq, Hkv, G, D) — so KV heads
    are never materialized repeated.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qf = q.reshape(b, sq, hkv, g, d).astype(COMPUTE_DTYPE)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    n_chunks = (sk + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(COMPUTE_DTYPE)
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(COMPUTE_DTYPE)
    kc = kp.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m_run, l_run, acc = carry
        idx, k_blk, v_blk = xs                      # (B, C, Hkv, D)
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        # scores: (B, Hkv, G, Sq, C) in fp32
        s = jnp.einsum("bqhgd,bchd->bhgqc", qf, k_blk).astype(jnp.float32) * scale
        mask = _mask_block(q_pos, k_pos, mask_spec)
        valid = k_pos < (sk if kv_valid_len is None else kv_valid_len)
        mask = mask & valid[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        # guard: rows with everything masked keep m=-inf; exp(-inf - -inf)=nan
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isneginf(m_run), 0.0, jnp.exp(m_run - m_safe))
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(COMPUTE_DTYPE), v_blk)
        acc = acc * corr[..., None].astype(COMPUTE_DTYPE) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), COMPUTE_DTYPE)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    denom = jnp.where(l_f > 0, l_f, 1.0)[..., None]
    out = (acc.astype(jnp.float32) / denom).astype(COMPUTE_DTYPE)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)  # (B,Sq,H,D)


# --------------------------------------------------------------------------
# Attention block (projections + rope + qk-norm + cache handling)
# --------------------------------------------------------------------------

def attention_schema(cfg, *, d_model=None, shards: int = 16):
    d = d_model or cfg.d_model
    h = cfg.padded_heads(shards)
    hkv = cfg.padded_kv_heads(shards)
    hd = cfg.resolved_head_dim
    sch = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "kv")),
        "wk": ParamSpec((d, hkv, hd), ("embed", "heads", "kv")),
        "wv": ParamSpec((d, hkv, hd), ("embed", "heads", "kv")),
        "wo": ParamSpec((h, hd, d), ("heads", "kv", "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamSpec((h, hd), ("heads", "kv"), init="zeros")
        sch["bk"] = ParamSpec((hkv, hd), ("heads", "kv"), init="zeros")
        sch["bv"] = ParamSpec((hkv, hd), ("heads", "kv"), init="zeros")
    if cfg.qk_norm:
        sch["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        sch["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return sch


def _qk_head_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def attention_block(
    p,
    x: jax.Array,                  # (B, S, D)
    cfg,
    *,
    mask_spec: AttnMaskSpec,
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,  # {"k","v": (B, Smax, Hkv, hd), "len": scalar}
    kv_chunk: int = 1024,
    kv_source: Optional[jax.Array] = None,  # cross-attention memory (B, Sm, D)
):
    """Returns (out (B,S,D), new_cache)."""
    xc = x.astype(COMPUTE_DTYPE)
    src = xc if kv_source is None else kv_source.astype(COMPUTE_DTYPE)
    q = constrain(jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(COMPUTE_DTYPE)), "heads")
    k = constrain(jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(COMPUTE_DTYPE)), "heads")
    v = constrain(jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(COMPUTE_DTYPE)), "heads")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(COMPUTE_DTYPE)
        k = k + p["bk"].astype(COMPUTE_DTYPE)
        v = v + p["bv"].astype(COMPUTE_DTYPE)
    if cfg.qk_norm:
        q = _qk_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_head_norm(k, p["k_norm"], cfg.norm_eps)

    use_rope = kv_source is None  # no rope on cross-attention memories
    if positions is None:
        positions = jnp.arange(x.shape[1])
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    q_offset = 0
    kv_valid = None
    if cache is not None:
        if "k" in cache and kv_source is None:
            idx = cache["len"]
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, idx, 0, 0))
            k, v = ck, cv
            kv_valid = idx + x.shape[1]
            q_offset = idx
            new_cache = {"k": ck, "v": cv, "len": kv_valid}
        else:
            new_cache = cache

    out = chunked_attention(
        q, k, v,
        mask_spec=mask_spec if kv_source is None else AttnMaskSpec(causal=False),
        q_offset=q_offset, kv_chunk=kv_chunk, kv_valid_len=kv_valid,
    )
    y = constrain(jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(COMPUTE_DTYPE)),
                  "residual")
    return y.astype(x.dtype), new_cache


def init_attn_cache(cfg, batch: int, max_len: int, *, shards: int = 16):
    hkv = cfg.padded_kv_heads(shards)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), COMPUTE_DTYPE),
        "v": jnp.zeros((batch, max_len, hkv, hd), COMPUTE_DTYPE),
        "len": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# Gated MLP (SwiGLU) and embeddings
# --------------------------------------------------------------------------

def mlp_schema(d: int, d_ff: int):
    return {
        "wi_gate": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wi_up": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d), ("mlp", "embed")),
    }


def mlp_block(p, x):
    xc = x.astype(COMPUTE_DTYPE)
    gate = jnp.einsum("bsd,df->bsf", xc, p["wi_gate"].astype(COMPUTE_DTYPE))
    up = jnp.einsum("bsd,df->bsf", xc, p["wi_up"].astype(COMPUTE_DTYPE))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(COMPUTE_DTYPE) * up
    out = jnp.einsum("bsf,fd->bsd", act, p["wo"].astype(COMPUTE_DTYPE))
    return constrain(out, "residual").astype(x.dtype)


def embedding_schema(vocab: int, d: int, *, tie: bool):
    sch = {"tokens": ParamSpec((vocab, d), ("vocab", "embed"), init="embed")}
    if not tie:
        sch["unembed"] = ParamSpec((d, vocab), ("embed", "vocab"))
    return sch


if jax.lax.optimization_barrier_p not in batching.primitive_batchers:
    # ... nor a batching rule: the barrier is identity-semantics (it only
    # pins XLA scheduling), so batching is bind-on-the-batched-operands
    # with the batch dims passed through unchanged.  Without this, any
    # vmap over a model forward (the FL engine's client axis) fails.
    def _optimization_barrier_batcher(args, dims, **params):
        outs = jax.lax.optimization_barrier_p.bind(*args, **params)
        return outs, dims

    batching.primitive_batchers[jax.lax.optimization_barrier_p] = (
        _optimization_barrier_batcher
    )


@jax.custom_jvp
def _grad_safe_barrier(x):
    # optimization_barrier has no differentiation rule on this JAX version;
    # the barrier only pins XLA scheduling on the primal, so the tangent
    # passes straight through (identity JVP, transposable for reverse mode).
    return jax.lax.optimization_barrier(x)


@_grad_safe_barrier.defjvp
def _grad_safe_barrier_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return jax.lax.optimization_barrier(x), dx


def embed(p, tokens):
    # optimization_barrier pins the table convert BEFORE the gather: without
    # it XLA converts after the gather and the vocab-shard partial-sum
    # all-reduce of the (B, S, D) activations runs in fp32 (2x bytes;
    # EXPERIMENTS.md §Perf pair B).
    table = _grad_safe_barrier(p["tokens"].astype(COMPUTE_DTYPE))
    return constrain(table[tokens], "residual")


def unembed(p, x, *, tie: bool):
    xc = x.astype(COMPUTE_DTYPE)
    if tie:
        w = p["tokens"].astype(COMPUTE_DTYPE).T
    else:
        w = p["unembed"].astype(COMPUTE_DTYPE)
    return jnp.einsum("bsd,dv->bsv", xc, w).astype(jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array, *, vocab_size: int):
    """Mean NLL; positions with label < 0 are masked; padded vocab excluded.

    Written sharding-aware: the gold logit is extracted with an iota-match
    contraction rather than take_along_axis — a vocab-dim gather forces SPMD
    to all-gather the full (B, S, V) fp32 logits across the tensor axis
    (74 GiB/step on qwen2-0.5b/train_4k; EXPERIMENTS.md §Perf iteration 0),
    while the contraction reduces shard-locally and all-reduces only (B, S).
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    vocab_pos = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    if v > vocab_size:
        logits = jnp.where(vocab_pos < vocab_size, logits, -1e9)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    hit = vocab_pos == jnp.maximum(labels, 0)[..., None]
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
