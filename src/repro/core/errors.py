"""Pinned error-message constants shared across config validation and drivers.

Several ValueError messages in this repo are *pinned*: tests match on their
text (``pytest.raises(match=...)``) and more than one module raises them —
``FLConfig.__post_init__`` validates at construction, ``ota.check_uplink``
re-validates call-site uplink overrides inside every fl.py driver, and
``fl._horizon_setup`` guards direct ``run_horizon_scanned`` calls.  Before
this module each site carried its own literal copy, so a wording tweak in
one place silently desynchronized the others (the FLConfig /
``ota.check_uplink`` drift hazard).

The single source of truth lives here as ``.format()`` templates.  The
``flcheck`` static-analysis pass (rule FLC006, ``tools/flcheck``) enforces
centralization: a ``raise ValueError`` whose literal duplicates one of
these messages anywhere outside this module is a lint error — new call
sites must import the constant.

Adding a message: define an UPPER_CASE ``str`` constant (optionally with
``{field}`` / ``{field!r}`` placeholders).  flcheck parses this file with
``ast`` only (never imports it) and derives each constant's longest
placeholder-free fragment as the duplication signature, so no registration
step is needed.
"""
from __future__ import annotations

# --- uplink-combination rules (ota.check_uplink; FLConfig re-raises) -------

ERR_UNKNOWN_UPLINK = "unknown uplink {uplink!r}; known: {modes}"

ERR_OTA_TOPK = (
    "uplink='ota' cannot apply top-k sparsification: analog "
    "superposition transmits the raw update vector over the "
    "air, never a per-device coded payload; set topk=1.0"
)

ERR_OTA_COMPRESSION = (
    "uplink='ota' requires compression='none': the PS receives "
    "the noisy analog sum and never decodes per-device "
    "payloads, so DoReFa quantization cannot apply"
)

ERR_OTA_MAPEL = (
    "uplink='ota' cannot use power_mode='mapel': MAPEL "
    "optimizes SIC decode rates, which analog superposition "
    "never performs; use power_mode='max' or 'ota-align'"
)

ERR_OTA_ALIGN_UPLINK = (
    "power_mode='ota-align' requires uplink='ota': alignment "
    "powers implement truncated channel inversion for the analog "
    "sum and have no digital-uplink meaning"
)

# --- horizon / policy coherence (FLConfig + fl._horizon_setup) -------------

ERR_SCAN_ONLINE_POLICY = (
    "horizon='scan' cannot drive online policy "
    "{scheduler!r}: it does not implement the traced selection "
    "protocol (scheduling.SchedulerPolicy: traced_protocol = True "
    "+ init_traced/select_round_traced), so its FL-state feedback "
    "needs the host round loop; use horizon='per-round' or add "
    "the traced protocol"
)

ERR_SCAN_ONLINE_MAPEL = (
    "horizon='scan' with online policy {scheduler!r} cannot use "
    "power_mode='mapel': the polyblock search is host-iterative "
    "and cannot run inside the traced round body; use "
    "power_mode='max' (or 'ota-align' under uplink='ota')"
)
