"""Launch layer: mesh construction, multi-pod dry-run, trainer, server."""
