"""flcheck static-analysis pass: corpus selftest, repo-clean gate, rule units.

Three layers:
  * the self-test corpus (``tools/flcheck/corpus``) must match its
    ``# expect: FLCxxx`` markers exactly — every rule with at least one
    positive and one negative snippet;
  * the repo tree itself must scan clean (the same gate CI runs);
  * unit tests for the judgment calls the rules encode: suppression
    comments, module-attribute vs bound-method disambiguation for FLC001,
    and jit-reachability for FLC003.
"""
import os
import textwrap

from tools.flcheck import checker
from tools.flcheck.selftest import run_selftest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_selftest_corpus_passes():
    assert run_selftest() == []


def test_repo_tree_scans_clean():
    errors_path = checker.find_errors_module([os.path.join(REPO, "src")])
    assert errors_path is not None
    fragments = checker.pinned_fragments(errors_path)
    assert fragments, "errors.py must yield at least one pinned fragment"
    diags = checker.check_paths(
        [os.path.join(REPO, d)
         for d in ("src", "tests", "benchmarks", "examples")],
        search_dirs=(os.path.join(REPO, "src"), REPO),
        fragments=fragments,
    )
    assert diags == [], "\n".join(str(d) for d in diags)


def test_every_rule_has_positive_and_negative_snippets():
    corpus = os.path.join(REPO, "tools", "flcheck", "corpus")
    sources = {
        f: open(os.path.join(corpus, f), encoding="utf-8").read()
        for f in os.listdir(corpus) if f.endswith(".py")
    }
    blob = "\n".join(sources.values())
    for rule in checker.RULES:
        assert f"# expect: {rule}" in blob, f"no positive snippet for {rule}"
    for src in sources.values():
        # a negative exemplar in every file: at least one function/stmt
        # that must stay silent (selftest enforces the silence itself)
        assert "good_" in src or "except ImportError" in src


def _scan(tmp_path, source, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return checker.check_paths([str(p)], search_dirs=(str(tmp_path),))


def test_suppression_comment_silences_one_rule(tmp_path):
    diags = _scan(tmp_path, """
        def f(s):
            return hash(s)  # flcheck: disable=FLC002
    """)
    assert diags == []


def test_bare_suppression_silences_all_rules(tmp_path):
    diags = _scan(tmp_path, """
        import jax

        def f(model, s):
            g = jax.jit(model.step)  # flcheck: disable
            return g(hash(s))  # flcheck: disable
    """)
    assert diags == []


def test_unsuppressed_hash_is_flagged(tmp_path):
    diags = _scan(tmp_path, """
        def f(s):
            return hash(s)
    """)
    assert [d.rule for d in diags] == ["FLC002"]


def test_module_attribute_jit_not_flagged(tmp_path):
    (tmp_path / "pkg").mkdir()
    (tmp_path / "pkg" / "__init__.py").write_text("")
    (tmp_path / "pkg" / "mod.py").write_text("def fn(x):\n    return x\n")
    diags = _scan(tmp_path, """
        import jax
        from pkg import mod

        def caller(x):
            return jax.jit(mod.fn)(x)
    """)
    assert diags == []


def test_bound_method_jit_flagged(tmp_path):
    diags = _scan(tmp_path, """
        import jax

        def caller(model, x):
            return jax.jit(model.fn)(x)
    """)
    assert [d.rule for d in diags] == ["FLC001"]


def test_flc003_needs_jit_reachability(tmp_path):
    src = textwrap.dedent("""
        import jax
        import jax.numpy as jnp

        def helper(x):
            s = jnp.sum(x)
            return float(s)
    """)
    assert _scan(tmp_path, src) == []
    # same helper, now called from a jit root: host sync becomes an error
    diags = _scan(tmp_path, src + textwrap.dedent("""
        @jax.jit
        def root(x):
            return helper(x)
    """))
    assert [d.rule for d in diags] == ["FLC003"]


def test_flc003_cross_file_reachability(tmp_path):
    (tmp_path / "helpers.py").write_text(textwrap.dedent("""
        import jax.numpy as jnp

        def helper(x):
            s = jnp.sum(x)
            return float(s)
    """))
    (tmp_path / "driver.py").write_text(textwrap.dedent("""
        import jax
        from helpers import helper

        @jax.jit
        def root(x):
            return helper(x)
    """))
    diags = checker.check_paths(
        [str(tmp_path / "helpers.py"), str(tmp_path / "driver.py")],
        search_dirs=(str(tmp_path),),
    )
    assert [(os.path.basename(d.path), d.rule) for d in diags] == [
        ("helpers.py", "FLC003")
    ]


def test_pinned_fragments_are_long_literals():
    errors_path = checker.find_errors_module([os.path.join(REPO, "src")])
    fragments = checker.pinned_fragments(errors_path)
    assert all(len(f) >= 24 for f in fragments)
    # every shared constant contributes a signature
    for const in ("ERR_OTA_TOPK", "ERR_OTA_COMPRESSION", "ERR_OTA_MAPEL",
                  "ERR_OTA_ALIGN_UPLINK", "ERR_SCAN_ONLINE_POLICY"):
        assert const in fragments.values()
