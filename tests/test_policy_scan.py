"""Traced online policies inside the scanned horizon (PR 10).

``horizon = "scan"`` now drives the online policies — update-aware,
age-fair, matching-pursuit — through the traced selection protocol:
scoring, group selection, power allocation and budget pricing all execute
inside ``fl_engine._online_horizon_core``'s scan body, with the policy's
FL-state feedback carried on device.  This file pins the contract against
the per-round driver:

* the equality grid — {update-aware, age-fair, matching-pursuit} x
  {noma, ota} plus T*K > M revisit horizons: identical device groups,
  bit-widths, rates, compression ratios and wall times (the host rebuilds
  the f64 logs from the realized schedule with the same per-round calls),
  accuracies equal to f32 tolerance;
* the vmapped seed sweep's row-0 identity on the online path;
* the cold-start convention (``COLD_START_NORM``): round 0 of a
  norm-fed policy ranks by the solo-rate table alone, identically on the
  per-round and traced paths;
* compile-count pins: the traced-online scan compiles a CONSTANT number
  of XLA programs across horizon lengths, and zero on an identical rerun.

Counting protocol: see tests/test_sanitizers.py — counts are
process-wide, so the counted horizon lengths here (7/12) must stay unique
across the whole tier-1 suite.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import channel, fl, scheduling
from repro.data import dirichlet_partition, make_mnist_like
from tools.flcheck.sanitizers import compile_count

M = 12

POLICIES = ("update-aware", "age-fair", "matching-pursuit")


@pytest.fixture(scope="module")
def world():
    ds = make_mnist_like(num_samples=800, seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.y_train, M, seed=0)
    return ds, cell, shards


@pytest.fixture(scope="module")
def tiny_world():
    """4-device cell: a 3-round, K=2 horizon revisits devices (T*K > M)."""
    ds = make_mnist_like(num_samples=400, seed=0)
    cell = channel.CellConfig(num_devices=4)
    shards = dirichlet_partition(ds.y_train, 4, seed=0)
    return ds, cell, shards


def _cfg(*, m=M, group_size=3, rounds=4, scheduler="update-aware",
         uplink="noma", horizon="per-round", seed=0, **kw):
    base = dict(num_devices=m, group_size=group_size, num_rounds=rounds,
                scheduler=scheduler, power_mode="max",
                compression="adaptive", fl_engine="batched",
                horizon=horizon, uplink=uplink, seed=seed)
    if uplink == "ota":
        # the OTA equality runs use a near-noiseless receiver: large
        # ota_noise makes max-power analog sums diverge on BOTH drivers,
        # which tests nothing about the scan
        base.update(compression="none", ota_noise=1e-9)
    base.update(kw)
    return FLConfig(**base)


def _run(world, cfg, *, eval_every=1):
    ds, cell, shards = world
    return fl.run_federated_learning(ds, shards, cell, cfg,
                                     eval_every=eval_every)


def _assert_equal_runs(a, b, *, acc_atol=0.0):
    """Same contract as tests/test_fl_scan.py: schedules, bits, rates,
    ratios and times identical; accuracies bit-equal by default (the scan
    body runs the same jitted training computation)."""
    assert [l.devices for l in a.logs] == [l.devices for l in b.logs]
    for la, lb in zip(a.logs, b.logs):
        np.testing.assert_array_equal(la.bits, lb.bits)
        np.testing.assert_array_equal(la.rates, lb.rates)
        np.testing.assert_array_equal(la.compression_ratios,
                                      lb.compression_ratios)
    np.testing.assert_array_equal(a.times(), b.times())
    np.testing.assert_allclose(a.accuracies(), b.accuracies(), atol=acc_atol)
    for x, y in zip(jax.tree_util.tree_leaves(a.final_params),
                    jax.tree_util.tree_leaves(b.final_params)):
        d = np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))
        assert d.mean() < 1e-6, f"mean param drift {d.mean()}"
        assert d.max() < 2e-2, f"max param drift {d.max()}"


# --------------------------------------------------------------------------
# equality grid: traced scan vs the per-round online loop
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", POLICIES)
@pytest.mark.parametrize("uplink", ["noma", "ota"])
def test_online_scan_equality_grid(world, uplink, scheduler):
    per_round = _run(world, _cfg(scheduler=scheduler, uplink=uplink))
    scanned = _run(world, _cfg(scheduler=scheduler, uplink=uplink,
                               horizon="scan"))
    _assert_equal_runs(per_round, scanned)


@pytest.mark.parametrize("scheduler", POLICIES)
def test_online_scan_equality_revisit_tail(tiny_world, scheduler):
    """T*K > M: online policies revisit devices (respects_c1 = False); the
    traced carry must keep ages/participation/norms straight across the
    revisits and the padded-lane OOB-drop must never touch device 0."""
    kw = dict(m=4, group_size=2, rounds=3, scheduler=scheduler, uplink="ota")
    per_round = _run(tiny_world, _cfg(**kw))
    scanned = _run(tiny_world, _cfg(horizon="scan", **kw))
    _assert_equal_runs(per_round, scanned)


def test_online_scan_ota_align_matches_per_round(world):
    """The traced power path covers 'ota-align' too (closed-form truncated
    channel inversion inside the scan body)."""
    kw = dict(scheduler="matching-pursuit", uplink="ota",
              power_mode="ota-align")
    per_round = _run(world, _cfg(**kw))
    scanned = _run(world, _cfg(horizon="scan", **kw))
    _assert_equal_runs(per_round, scanned)


def test_online_scan_eval_every_forward_fill(world):
    """Skipped-eval rounds short-circuit inside the online scan body
    (lax.cond -> NaN) and the host forward-fills, like the precomputed
    scan."""
    per_round = _run(world, _cfg(rounds=4), eval_every=3)
    scanned = _run(world, _cfg(rounds=4, horizon="scan"), eval_every=3)
    _assert_equal_runs(per_round, scanned)
    accs = scanned.accuracies()
    assert accs[1] == accs[0] and accs[2] == accs[0]
    assert not np.isnan(accs).any()


def test_online_vmapped_row0_matches_single(world):
    """Row s of the online vmapped sweep is the same traced program the
    single-seed driver runs — row 0 bit-identical, other seeds distinct."""
    ds, cell, shards = world
    cfg = _cfg(rounds=3, horizon="scan")
    single = fl.run_federated_learning(ds, shards, cell, cfg)
    sweep = fl.run_horizon_vmapped(ds, shards, cell, cfg, seeds=[0, 1, 2])
    assert len(sweep) == 3
    r0 = sweep[0]
    assert [l.devices for l in r0.logs] == [l.devices for l in single.logs]
    np.testing.assert_array_equal(r0.accuracies(), single.accuracies())
    np.testing.assert_array_equal(r0.times(), single.times())
    for x, y in zip(jax.tree_util.tree_leaves(r0.final_params),
                    jax.tree_util.tree_leaves(single.final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(
        [l.devices for l in sweep[s].logs] != [l.devices for l in r0.logs]
        or not np.array_equal(sweep[s].accuracies(), r0.accuracies())
        for s in (1, 2)
    )


def test_online_cell_sweep_matches_individual_runs(tiny_world):
    """Each (cell, seed) instance of the online sweep grid equals the
    standalone run at that instance's seed."""
    ds, cell, shards = tiny_world
    cfg = _cfg(m=4, group_size=2, rounds=3, scheduler="age-fair",
               horizon="scan")
    grid = fl.run_cell_sweep(ds, shards, cell, cfg, num_cells=2,
                             seeds_per_cell=2)
    for c in range(2):
        for s in range(2):
            inst = fl.run_federated_learning(
                ds, shards, cell, dataclasses.replace(cfg, seed=c * 2 + s))
            assert ([l.devices for l in grid[c][s].logs]
                    == [l.devices for l in inst.logs])
            np.testing.assert_array_equal(grid[c][s].accuracies(),
                                          inst.accuracies())
            np.testing.assert_array_equal(grid[c][s].times(), inst.times())


# --------------------------------------------------------------------------
# cold start: the COLD_START_NORM convention, shared by both paths
# --------------------------------------------------------------------------

def test_cold_start_norm_is_shared_and_documented():
    """Every norm-fed policy declares its documented cold-start estimate;
    update-aware and matching-pursuit share the same stand-in."""
    ua = scheduling.get_policy("update-aware")
    mp = scheduling.get_policy("matching-pursuit")
    assert ua.COLD_START_NORM == mp.COLD_START_NORM == 1.0


@pytest.mark.parametrize("horizon", ["per-round", "scan"])
def test_cold_start_round0_ranks_by_solo_rate(world, horizon):
    """Round 0 of update-aware: no update has been observed, every norm
    estimate is COLD_START_NORM, so the score reduces to the solo-rate
    table — the selected group is the solo-rate top-K, identically on the
    per-round and traced paths (the fl_engine cold-start caveat, pinned)."""
    ds, cell, shards = world
    cfg = _cfg(rounds=2, horizon=horizon)
    res = fl.run_federated_learning(ds, shards, cell, cfg)

    # replay the driver's PRNG folds to get the same channel table
    key = jax.random.PRNGKey(cfg.seed)
    dist = channel.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(channel.sample_round_channels(
        jax.random.fold_in(key, 2), dist, cell, cfg.num_rounds))
    sizes = np.array([len(s) for s in shards], dtype=np.float64)
    weights = sizes / sizes.sum()

    policy = scheduling.get_policy("update-aware")
    solo = policy.init_traced(gains, weights, fl.policy_config(cell, cfg))[
        "solo"]
    expected = tuple(
        int(d) for d in
        np.argsort(-solo[0], kind="stable")[:cfg.group_size]
    )
    assert res.logs[0].devices == expected


# --------------------------------------------------------------------------
# compile-count pins: the traced-online scan is ONE program per horizon
# --------------------------------------------------------------------------

CC_M = 6


@pytest.fixture(scope="module")
def compile_world():
    ds = make_mnist_like(num_samples=300, seed=0)
    cell = channel.CellConfig(num_devices=CC_M)
    shards = dirichlet_partition(ds.y_train, CC_M, seed=0)
    return ds, cell, shards


def _ccfg(rounds, *, seed=0):
    return FLConfig(num_devices=CC_M, group_size=2, num_rounds=rounds,
                    scheduler="update-aware", power_mode="max",
                    compression="adaptive", fl_engine="batched",
                    horizon="scan", seed=seed)


def _warm_key_splits(*sizes):
    key = jax.random.PRNGKey(0)
    for n in sizes:
        jax.random.split(key, n)


def test_online_scan_compile_count_constant_in_rounds(compile_world):
    """The traced-online driver compiles a constant number of programs
    regardless of horizon length — selection, power, budgets, training
    and eval all live inside the one scanned program (a per-round retrace
    would scale the count with T), and an identical rerun is fully
    cached.  The counted sizes 7/12 are suite-unique (see module
    docstring)."""
    ds, cell, shards = compile_world
    fl.run_federated_learning(ds, shards, cell, _ccfg(3))   # warm T=3
    _warm_key_splits(7, 12)
    counts = {}
    for t_rounds in (7, 12):
        with compile_count() as tally:
            fl.run_federated_learning(ds, shards, cell, _ccfg(t_rounds))
        counts[t_rounds] = tally.count
    assert counts[7] == counts[12], (
        f"online scan driver compile count scales with rounds: {counts}"
    )
    assert counts[7] > 0   # each T is a fresh static shape: must compile

    with compile_count() as tally:
        fl.run_federated_learning(ds, shards, cell, _ccfg(7))
    assert tally.count == 0, "identical rerun must be fully cached"
