"""Device-resident padded client data bank (the batched FL engine's input).

The legacy FL loop re-pads and re-uploads every scheduled device's shard from
host on every round (one ``local_update`` host round-trip per device).  The
bank pays that cost exactly once: all M shards are padded to a common batch
grid and uploaded as two device-resident tensors

    xb: (M, n_batches, batch_size, *feat)   x_train.dtype
    yb: (M, n_batches, batch_size, *lab)    int32, -1 marks padding

where ``feat``/``lab`` are whatever trailing shape the dataset carries —
``(D,)`` flat image features with scalar labels (the paper's MNIST-like
setup), or ``(S,)`` token rows with ``(S,)`` next-token labels
(:func:`repro.data.tokens.make_token_dataset`).  A round is a K-row gather
(``xb[dev_idx]``) inside the jitted round step instead of K host->device
copies.  Padding positions carry label -1, the validity convention every
FLModel loss masks on, so a shard shorter than the common grid trains
identically to its legacy per-shard padding: the extra all-padding batches
produce exactly-zero gradients and leave the parameters untouched.

Memory: the bank is the dataset re-laid-out per device plus padding up to
the *largest* shard's batch count, i.e. O(M * max_k ceil(|D_k|/bs) * bs *
prod(feat)) elements — at paper scale (M=300, MNIST-like) tens of MB, but a
skewed Dirichlet partition at large M pads every client to the single
largest shard and the bill grows as M * max_k instead of sum_k.  ``build``
warns (``ClientBank.nbytes`` / :func:`_device_memory_limit`) when the
padded bank would claim more than ``DEFAULT_MEM_FRACTION`` of the
accelerator's memory and points at :class:`BucketedClientBank`, which
groups clients into power-of-two batch-count buckets so within-bucket
padding is bounded below 2x.

The same gather idiom serves per-round *evaluation*: :class:`EvalBank`
keeps the test set resident on device, and :func:`eval_sample_plan`
precomputes a seeded (T, n) row-index plan so a client-sampled eval is one
gather + batched forward inside the jitted round step (or the scanned
horizon) — with ``frac = 1`` the gather is skipped entirely and the eval
is bit-identical to the full-test-set accuracy call it replaces.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

EVAL_SEED_OFFSET = 23
# decorrelates the eval-sampling stream from the model-init / channel /
# scheduling streams that consume FLConfig.seed (the scheduling permutation
# already claims +17 — see scheduling.RandomPolicy.SEED_OFFSET)

DEFAULT_MEM_FRACTION = 0.5
# fraction of the device's reported memory a padded bank may claim before
# ``build`` warns and recommends the bucketed layout


def _device_memory_limit() -> "int | None":
    """Device memory in bytes, or None when the backend doesn't report it
    (CPU).  Separated out so tests can monkeypatch a limit in."""
    try:
        stats = jax.devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    return stats.get("bytes_limit")


def _check_bank_memory(projected_bytes: int, mem_fraction: float) -> None:
    limit = _device_memory_limit()
    if limit is None or limit <= 0:
        return
    if projected_bytes > mem_fraction * limit:
        warnings.warn(
            f"padded ClientBank would hold {projected_bytes / 2**20:.0f} MiB "
            f"(> {mem_fraction:.0%} of the device's {limit / 2**20:.0f} MiB):"
            f" skewed shard sizes pad every client to the largest shard; "
            f"use FLConfig(client_bank='bucketed') (BucketedClientBank) to "
            f"bound the padding, or shrink the dataset / batch grid",
            ResourceWarning,
            stacklevel=3,
        )


def _padded_arrays(x_train, y_train, shards, batch_size, nb):
    """Shared shard->grid layout: (m, nb*bs, *trail) arrays, -1 label pad."""
    m = len(shards)
    bs = int(batch_size)
    xb = np.zeros((m, nb * bs, *x_train.shape[1:]), x_train.dtype)
    yb = np.full((m, nb * bs, *y_train.shape[1:]), -1, np.int32)
    for k, idx in enumerate(shards):
        n = len(idx)
        xb[k, :n] = x_train[idx]
        yb[k, :n] = y_train[idx]
    feat, lab = x_train.shape[1:], y_train.shape[1:]
    return (
        xb.reshape(m, nb, bs, *feat),
        yb.reshape(m, nb, bs, *lab),
    )


@dataclasses.dataclass
class ClientBank:
    """All M client shards, padded and resident on device."""

    xb: jax.Array        # (M, NB, BS, *feat) x_train dtype
    yb: jax.Array        # (M, NB, BS, *lab) int32; -1 marks padding
    sizes: np.ndarray    # (M,) realized shard sizes (host, for FedAvg weights)

    @property
    def num_devices(self) -> int:
        return self.xb.shape[0]

    @property
    def batch_size(self) -> int:
        return self.xb.shape[2]

    @property
    def nbytes(self) -> int:
        """Device bytes the bank holds (both tensors, padding included)."""
        return int(self.xb.nbytes) + int(self.yb.nbytes)

    @staticmethod
    def _ceil_batches(n: int, batch_size: int) -> int:
        """The grid rule: batches needed to cover n samples (min 1)."""
        return max(1, int(-(-int(n) // int(batch_size))))

    def n_batches_for(self, devs) -> int:
        """Batches covering the given devices' shards — the batched engine
        slices the global grid down to this per round (same rule as
        ``build``, single owner), clamped to the bank's own grid."""
        if not len(devs):
            return 1
        need = self._ceil_batches(self.sizes[list(devs)].max(), self.batch_size)
        return min(need, self.xb.shape[1])

    @classmethod
    def build(
        cls, x_train: np.ndarray, y_train: np.ndarray, shards: list,
        batch_size: int, *, mem_fraction: float = DEFAULT_MEM_FRACTION,
    ) -> "ClientBank":
        """Pad all shards once to the common (n_batches, batch_size) grid.

        Sample order inside each shard is preserved (shards arrive
        pre-shuffled from the partitioner), so batch b of device k holds
        exactly the samples the legacy ``local_update`` would put there.
        Works for any trailing feature/label shape: flat image rows with
        scalar labels, or (S,) token rows with (S,) shifted labels.
        """
        m = len(shards)
        bs = int(batch_size)
        sizes = np.array([len(s) for s in shards], dtype=np.intp)
        nb = cls._ceil_batches(sizes.max(), bs) if m else 1
        itemsize = np.dtype(x_train.dtype).itemsize
        feat = int(np.prod(x_train.shape[1:], dtype=np.int64)) if x_train.ndim > 1 else 1
        lab = int(np.prod(y_train.shape[1:], dtype=np.int64)) if y_train.ndim > 1 else 1
        projected = m * nb * bs * (feat * itemsize + lab * 4)
        _check_bank_memory(projected, mem_fraction)
        xb, yb = _padded_arrays(x_train, y_train, shards, bs, nb)
        return cls(xb=jnp.asarray(xb), yb=jnp.asarray(yb), sizes=sizes)


@dataclasses.dataclass
class BucketedClientBank:
    """Size-bucketed client banks: pow-2 batch grids instead of one max grid.

    Clients are grouped by ``next_pow2(ceil(|D_k| / bs))``, and each bucket
    is padded only to its own power-of-two batch count, so within-bucket
    padding is bounded below 2x the client's own need — a skewed Dirichlet
    partition stops billing every small client for the single largest
    shard.  A round's K-row gather now spans several buckets, so it runs
    as per-bucket gathers + a batch-axis pad/slice to the round's common
    ``nb`` + an inverse permutation back to schedule order
    (:meth:`gather`, device-side).  The gathered rows are element-equal to
    the padded bank's ``xb[devs, :nb]``, so training through either layout
    is bit-identical (pinned in tests/test_client_bank.py).

    Batched per-round engine only: the scan horizon indexes one dense
    (M, NB, ...) tensor inside the traced program and cannot span buckets.
    """

    buckets: list        # list of (xb, yb) device-array pairs, (m_b, NB_b, BS, ...)
    bucket_of: np.ndarray   # (M,) bucket index per client
    row_of: np.ndarray      # (M,) row of the client inside its bucket
    sizes: np.ndarray       # (M,) realized shard sizes

    @property
    def num_devices(self) -> int:
        return len(self.sizes)

    @property
    def batch_size(self) -> int:
        return self.buckets[0][0].shape[2]

    @property
    def nbytes(self) -> int:
        return sum(int(xb.nbytes) + int(yb.nbytes) for xb, yb in self.buckets)

    def n_batches_for(self, devs) -> int:
        """Same single-owner grid rule as :meth:`ClientBank.n_batches_for`,
        clamped to the largest bucket grid."""
        if not len(devs):
            return 1
        need = ClientBank._ceil_batches(
            self.sizes[list(devs)].max(), self.batch_size
        )
        cap = max(xb.shape[1] for xb, _ in self.buckets)
        return min(need, cap)

    def gather(self, devs, nb: int):
        """Gather the scheduled rows as (K, nb, BS, ...) device tensors.

        Per-bucket gather, pad/slice every bucket's batch axis to the
        round's ``nb`` (pad rows carry label -1 — the shared validity
        convention, so they are exactly-zero-gradient), then invert the
        bucket-order permutation so row k is device ``devs[k]``.
        """
        devs = np.asarray(devs, dtype=np.intp)
        order = np.argsort(self.bucket_of[devs], kind="stable")
        inv = np.argsort(order, kind="stable")
        xs, ys = [], []
        for b in devs[order]:
            xb, yb = self.buckets[self.bucket_of[b]]
            row = int(self.row_of[b])
            x, y = xb[row], yb[row]
            have = x.shape[0]
            if have >= nb:
                x, y = x[:nb], y[:nb]
            else:
                pad = nb - have
                x = jnp.concatenate(
                    [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0
                )
                y = jnp.concatenate(
                    [y, jnp.full((pad, *y.shape[1:]), -1, y.dtype)], axis=0
                )
            xs.append(x)
            ys.append(y)
        x = jnp.stack(xs)[jnp.asarray(inv)]
        y = jnp.stack(ys)[jnp.asarray(inv)]
        return x, y

    @classmethod
    def build(
        cls, x_train: np.ndarray, y_train: np.ndarray, shards: list,
        batch_size: int, *, mem_fraction: float = DEFAULT_MEM_FRACTION,
    ) -> "BucketedClientBank":
        del mem_fraction  # bucketing IS the remedy; accepted for API parity
        bs = int(batch_size)
        sizes = np.array([len(s) for s in shards], dtype=np.intp)
        need = np.array(
            [ClientBank._ceil_batches(n, bs) for n in sizes], dtype=np.intp
        )
        pow2 = 1 << np.ceil(np.log2(need)).astype(np.intp)
        levels = sorted(set(int(p) for p in pow2))
        bucket_of = np.zeros(len(shards), np.intp)
        row_of = np.zeros(len(shards), np.intp)
        buckets = []
        for bi, nb in enumerate(levels):
            members = [k for k in range(len(shards)) if int(pow2[k]) == nb]
            bucket_of[members] = bi
            row_of[members] = np.arange(len(members))
            xb, yb = _padded_arrays(
                x_train, y_train, [shards[k] for k in members], bs, nb
            )
            buckets.append((jnp.asarray(xb), jnp.asarray(yb)))
        return cls(
            buckets=buckets, bucket_of=bucket_of, row_of=row_of, sizes=sizes
        )


@dataclasses.dataclass
class EvalBank:
    """The test set, resident on device for gathered per-round evaluation.

    No padding: a sampled eval gathers exactly ``n`` rows (fixed shape per
    horizon), so the masked-accuracy bookkeeping the training bank needs
    never enters the eval path and the ``frac = 1`` case stays bit-identical
    to the full accuracy call over the raw arrays.
    """

    xe: jax.Array        # (N, *feat)
    ye: jax.Array        # (N, *lab)

    @property
    def num_samples(self) -> int:
        return self.xe.shape[0]

    @classmethod
    def build(cls, x_test: np.ndarray, y_test: np.ndarray) -> "EvalBank":
        return cls(xe=jnp.asarray(x_test), ye=jnp.asarray(y_test))


def eval_sample_plan(
    num_test: int, frac: float, num_rounds: int, seed: int
) -> "np.ndarray | None":
    """Seeded (T, n) eval-row gather plan, or ``None`` for a full eval.

    One draw per round for *every* round (not only eval rounds), so the
    per-round driver and the scanned horizon — which may skip different
    rounds under ``eval_every`` — index an identical plan at matching ``t``
    and report identical sampled accuracies.  n = ceil(frac * N), without
    replacement within a round.
    """
    if frac >= 1.0:
        return None
    n = max(1, int(np.ceil(frac * num_test)))
    rng = np.random.default_rng(seed + EVAL_SEED_OFFSET)
    return np.stack(
        [rng.choice(num_test, size=n, replace=False) for _ in range(num_rounds)]
    ).astype(np.int32)
