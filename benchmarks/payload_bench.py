"""Large-payload aggregation benchmark: chunked Pallas kernel vs XLA einsum.

The batched FL engine reduces K quantized client updates into one weighted
sum per round — at LeNet scale that is a (K, 266,610) einsum, but the
model-agnostic payload path (``FLConfig.model``) moves transformer-class
update vectors (10^6-10^8 params, qwen2_0_5b is ~4.9e8).  This bench
measures the two aggregation backends the engine can take at those sizes:

  * ``einsum``  — the XLA default (``jnp.einsum("k,kn->n", coeff, codes)``
    with the dequant scales folded into the coefficients), and
  * ``pallas``  — :func:`repro.kernels.aggregate.weighted_aggregate_pallas`,
    which now chunks the parameter axis (``lax.map`` over (K, chunk_elems)
    slabs) so the padded tile grid for the whole payload is never resident
    at once.

Payload sizes are anchored on the FL model zoo: the smallest point is the
``tiny-transformer-1m`` payload the compression stack is pinned on, and
every record carries ``qwen2_frac`` — the fraction of the full qwen2-0.5B
parameter count (schema-derived, nothing materialized) the point covers.
On this CPU the Pallas path runs in interpret mode and loses to the einsum
by design (see ROADMAP: Mosaic-on-TPU is where the kernel is meant to
win); the bench records both so the crossover is visible the day the
hardware changes.  ``benchmarks/run.py`` persists the records to
``BENCH_payload.json`` (``BENCH_payload_fast.json`` under --fast/--smoke)
and gates both medians under ``--check-regression``.
"""
from __future__ import annotations

import functools
import gc
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.aggregate import DEFAULT_CHUNK_ELEMS, weighted_aggregate_pallas

K = 4          # clients per round (paper-scale group sizes are 2-16)
BITS = 8       # mid-range adaptive width; levels a = 2^b - 1 per client

# tiny-transformer-1m payload (the >=1e6-param FL pin) up to 2^25 params —
# ~6.8% of qwen2-0.5B, the largest slab the interpret-mode kernel sweeps in
# reasonable single-core time (the math is size-linear beyond the chunk).
FULL_SIZES = (1_122_624, 8_388_608, 33_554_432)
FAST_SIZES = (262_144, 1_122_624)


def _qwen2_params() -> int:
    """Full qwen2-0.5B parameter count, derived from the schema only."""
    from repro.configs import get_config
    from repro.models.registry import _FAMILIES

    cfg = get_config("qwen2_0_5b")
    schema = _FAMILIES[cfg.family].schema(cfg, shards=1)
    return sum(
        int(np.prod(leaf.shape))
        for leaf in jax.tree_util.tree_leaves(
            schema, is_leaf=lambda x: hasattr(x, "shape")
        )
    )


# module-level jitted backends (flcheck FLC001): a jit(lambda) built inside
# the size loop is a fresh function object per size, so every call misses
# the jit cache and the benchmark times retracing, not the kernel
@jax.jit
def _einsum_aggregate(codes, coeff):
    return jnp.einsum("k,kn->n", coeff, codes)


@jax.jit
def _pallas_aggregate(codes, scales, weights, levels):
    return weighted_aggregate_pallas(codes, scales, weights, levels=levels)


def _best_seconds(fn, arg, *, passes: int) -> float:
    """Warm-compile once, then best-of-``passes`` wall seconds."""
    fn(arg).block_until_ready()
    best = np.inf
    for _ in range(passes):
        t0 = time.perf_counter()
        fn(arg).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return float(best)


def main(fast: bool = False) -> dict:
    sizes = FAST_SIZES if fast else FULL_SIZES
    qwen2 = _qwen2_params()
    rng = np.random.default_rng(0)
    scales = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    levels = jnp.asarray(np.full(K, float(2**BITS - 1), np.float32))
    weights = jnp.asarray(rng.uniform(0.1, 1.0, K).astype(np.float32))
    coeff = weights * scales / levels

    records = []
    for p in sizes:
        gc.collect()   # drop the previous size's (K, P) block now
        codes = jnp.asarray(
            rng.integers(-(2**BITS - 1), 2**BITS, (K, p)).astype(np.float32)
        )
        einsum_fn = functools.partial(_einsum_aggregate, coeff=coeff)
        pallas_fn = functools.partial(
            _pallas_aggregate, scales=scales, weights=weights, levels=levels
        )
        passes = 3 if p <= 2**23 else 2
        einsum_s = _best_seconds(einsum_fn, codes, passes=passes)
        pallas_s = _best_seconds(pallas_fn, codes, passes=passes)
        # the two backends must agree (chunk boundaries don't touch the
        # math); a bench that silently diverged would be worthless
        diff = float(jnp.max(jnp.abs(einsum_fn(codes) - pallas_fn(codes))))
        assert diff < 1e-5 * float(p) ** 0.5, f"backends diverge: {diff}"
        chunks = -(-p // DEFAULT_CHUNK_ELEMS)
        records.append({
            "params": int(p), "k": K, "bits": BITS, "chunks": int(chunks),
            "qwen2_frac": round(p / qwen2, 4),
            "einsum_s": einsum_s,
            "pallas_chunked_s": pallas_s,
            # "speedup" prefix: excluded from the --check-regression
            # record identity key (a derived ratio, tracked not gated)
            "speedup_einsum_over_pallas": round(pallas_s / einsum_s, 2),
        })
        emit(f"payload.einsum_P{p}_K{K}", einsum_s * 1e6)
        emit(f"payload.pallas_chunked_P{p}_K{K}", pallas_s * 1e6,
             f"einsum {pallas_s / einsum_s:.1f}x faster (CPU interpret)")
        del codes
    return {
        "suite": "payload_aggregation",
        "settings": {
            "k": K, "bits": BITS, "chunk_elems": int(DEFAULT_CHUNK_ELEMS),
            "qwen2_0_5b_params": int(qwen2),
            "backend": jax.default_backend(),
            "pallas_mode": "interpret (CPU)",
        },
        "records": records,
    }


if __name__ == "__main__":
    main()
