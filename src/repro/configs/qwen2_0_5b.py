"""Qwen2-0.5B: dense, GQA (kv=2), QKV bias, tied embeddings [arXiv:2407.10671].

14 heads do not divide the 16-way tensor axis; padded_heads(16) pads Q to 16
(zero-init extra heads), recorded in DESIGN.md §4."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, head_dim=64,
    qkv_bias=True, tie_embeddings=True,
    source="arXiv:2407.10671",
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64,
    qkv_bias=True, tie_embeddings=True,
    source="reduced qwen2 family",
)
