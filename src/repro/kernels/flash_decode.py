"""Pallas TPU flash-decode attention kernel (beyond-paper, serving path).

One-token grouped-query attention over a long KV cache — the memory-bound
inner loop of decode_32k / long_500k. Tiling: grid (B, Hkv, S-blocks); each
(batch, kv-head) instance streams (BLOCK_S, D) cache tiles HBM->VMEM and
maintains the online-softmax state (m, l, acc) in VMEM scratch across the
sequential minor grid dimension — the canonical TPU flash-decode schedule.
Invalid cache tail (positions >= valid_len) is masked, and fully-invalid
blocks short-circuit via @pl.when (no MXU work issued).

Validated in interpret mode against ref.flash_decode_ref
(tests/test_kernels.py); on real TPU hardware the same pallas_call lowers
to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 256
NEG_INF = -1e30


def _kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, n_blocks: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = vl_ref[0]
    block_start = i * block_s

    @pl.when(block_start < valid_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (BS, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        d = q.shape[-1]
        s = q @ k.T * (1.0 / (d ** 0.5))             # (G, BS)
        pos = block_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < valid_len, s, NEG_INF)

        m_old = m_ref[:, 0]                          # (G,)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(pos < valid_len, p, 0.0)
        corr = jnp.exp(m_old - m_new)
        l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(i == n_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,          # (B, Hkv, G, D) current-token queries, grouped
    k: jax.Array,          # (B, S, Hkv, D) cache
    v: jax.Array,          # (B, S, Hkv, D)
    valid_len: jax.Array,  # scalar int32: #valid cache positions
    *,
    block_s: int = BLOCK_S,
    interpret: bool = True,
) -> jax.Array:
    b, hkv, g, d = q.shape
    s = k.shape[1]
    assert s % block_s == 0, (s, block_s)
    n_blocks = s // block_s
    grid = (b, hkv, n_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, n_blocks=n_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),                      # valid_len
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),   # m (running max, lane-bcast)
            pltpu.VMEM((g, 128), jnp.float32),   # l (running denom)
            pltpu.VMEM((g, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(valid_len.reshape(1).astype(jnp.int32), q, k, v)
