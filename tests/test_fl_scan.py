"""Scanned-horizon equality grid: one-lax.scan driver vs the per-round loop.

``cfg.horizon = "scan"`` folds a precomputed-schedule horizon into ONE
device program (fl_engine.run_horizon).  It must reproduce the per-round
batched driver across uplink x compression x policy: identical device
groups, bit-widths, rates, compression ratios and wall times (all computed
from the same host plan), with accuracies equal to f32 tolerance — in
practice bit-identical, since the scan body is the same
``_train_quantize_aggregate`` jitted computation.  Also pinned here: the
T*K > M empty-tail padding (zero agg weights multiply padded rows out of
the aggregate exactly), the vmapped seed sweep's row-0 identity, the
shard_map'd cell sweep (on multi-device hosts), the client-sampled eval
plan shared by both drivers, and the untraced-online-policy rejection
(traced-protocol policies run under the scan — tests/test_policy_scan.py).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like

M = 12


@pytest.fixture(scope="module")
def world():
    ds = make_mnist_like(num_samples=800, seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.y_train, M, seed=0)
    return ds, cell, shards


@pytest.fixture(scope="module")
def tiny_world():
    """4-device cell so a 3-round, K=2 horizon exhausts the device set."""
    ds = make_mnist_like(num_samples=400, seed=0)
    cell = channel.CellConfig(num_devices=4)
    shards = dirichlet_partition(ds.y_train, 4, seed=0)
    return ds, cell, shards


def _cfg(*, m=M, group_size=3, rounds=3, scheduler="lazy-gwmin",
         compression="adaptive", horizon="per-round", eval_sample=1.0,
         seed=0):
    return FLConfig(num_devices=m, group_size=group_size, num_rounds=rounds,
                    scheduler=scheduler, power_mode="max",
                    compression=compression, fl_engine="batched",
                    horizon=horizon, eval_sample=eval_sample, seed=seed)


def _run(world, cfg, *, uplink="noma", eval_every=1):
    ds, cell, shards = world
    return fl.run_federated_learning(ds, shards, cell, cfg, uplink=uplink,
                                     eval_every=eval_every)


def _assert_equal_runs(a, b, *, acc_atol=0.0):
    """Scan vs per-round: schedules/bits/rates/ratios/times must be
    identical (same host plan, same traced bits); accuracies bit-equal by
    default — both drivers run the same jitted eval computation."""
    assert [l.devices for l in a.logs] == [l.devices for l in b.logs]
    for la, lb in zip(a.logs, b.logs):
        np.testing.assert_array_equal(la.bits, lb.bits)
        np.testing.assert_array_equal(la.rates, lb.rates)
        np.testing.assert_array_equal(la.compression_ratios,
                                      lb.compression_ratios)
    np.testing.assert_array_equal(a.times(), b.times())
    np.testing.assert_allclose(a.accuracies(), b.accuracies(), atol=acc_atol)
    for x, y in zip(jax.tree_util.tree_leaves(a.final_params),
                    jax.tree_util.tree_leaves(b.final_params)):
        d = np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64))
        assert d.mean() < 1e-6, f"mean param drift {d.mean()}"
        assert d.max() < 2e-2, f"max param drift {d.max()}"


# lazy-gwmin: the paper's precomputed MWIS policy; random: the §IV baseline
# with its own PRNG stream — both precomputed, both must plan identically
# in either driver
@pytest.mark.parametrize("scheduler", ["lazy-gwmin", "random"])
@pytest.mark.parametrize("compression", ["adaptive", "none"])
@pytest.mark.parametrize("uplink", ["noma", "tdma"])
def test_scan_equality_grid(world, uplink, compression, scheduler):
    per_round = _run(world, _cfg(compression=compression,
                                 scheduler=scheduler), uplink=uplink)
    scanned = _run(world, _cfg(compression=compression, scheduler=scheduler,
                               horizon="scan"), uplink=uplink)
    _assert_equal_runs(per_round, scanned)


@pytest.mark.parametrize("scheduler", ["round-robin", "lazy-gwmin"])
@pytest.mark.parametrize("uplink", ["noma", "tdma"])
def test_scan_equality_empty_tail_rounds(tiny_world, uplink, scheduler):
    """T*K > M schedules end in short/empty groups; the scan pads them with
    zero-weight rows and must log them identically (no training, wall
    clock still advances)."""
    kw = dict(m=4, group_size=2, rounds=3, scheduler=scheduler)
    per_round = _run(tiny_world, _cfg(**kw), uplink=uplink)
    scanned = _run(tiny_world, _cfg(horizon="scan", **kw), uplink=uplink)
    if scheduler == "round-robin":
        assert scanned.logs[-1].devices == ()
        assert scanned.logs[-1].bits.size == 0
    _assert_equal_runs(per_round, scanned)


def test_scan_eval_every_forward_fill(world):
    """eval_every > 1: the scan skips those rounds' eval inside the program
    (lax.cond -> NaN) and the host forward-fills — same repeated-accuracy
    logs as the per-round driver, final round always evaluated."""
    per_round = _run(world, _cfg(rounds=4), eval_every=3)
    scanned = _run(world, _cfg(rounds=4, horizon="scan"), eval_every=3)
    _assert_equal_runs(per_round, scanned)
    accs = scanned.accuracies()
    assert accs[1] == accs[0] and accs[2] == accs[0]  # forward-filled
    assert not np.isnan(accs).any()


def test_scan_eval_sample_matches_per_round(world):
    """Client-sampled eval: both drivers consume the same (T, n) plan, so
    the sampled accuracies are bit-identical too."""
    per_round = _run(world, _cfg(eval_sample=0.5))
    scanned = _run(world, _cfg(eval_sample=0.5, horizon="scan"))
    _assert_equal_runs(per_round, scanned)


def test_vmapped_seeds_row0_matches_single(world):
    """Row s of the vmapped sweep is the same program run_horizon_scanned
    runs for that seed alone — row 0 must be bit-identical to the
    single-seed run, and different seeds must actually differ."""
    ds, cell, shards = world
    cfg = _cfg(horizon="scan")
    single = fl.run_federated_learning(ds, shards, cell, cfg)
    sweep = fl.run_horizon_vmapped(ds, shards, cell, cfg, seeds=[0, 1, 2])
    assert len(sweep) == 3
    r0 = sweep[0]
    assert [l.devices for l in r0.logs] == [l.devices for l in single.logs]
    np.testing.assert_array_equal(r0.accuracies(), single.accuracies())
    np.testing.assert_array_equal(r0.times(), single.times())
    for x, y in zip(jax.tree_util.tree_leaves(r0.final_params),
                    jax.tree_util.tree_leaves(single.final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # seeds are real: some other row differs from seed 0 somewhere
    assert any(
        [l.devices for l in sweep[s].logs] != [l.devices for l in r0.logs]
        or not np.array_equal(sweep[s].accuracies(), r0.accuracies())
        for s in (1, 2)
    )


def test_cell_sweep_matches_individual_scans(tiny_world):
    """Each (cell, seed) instance of the sweep grid equals the standalone
    scanned run at that instance's seed (1-device mesh here; the sharded
    test below pins multi-device meshes against this program)."""
    ds, cell, shards = tiny_world
    cfg = _cfg(m=4, group_size=2, rounds=3, horizon="scan")
    grid = fl.run_cell_sweep(ds, shards, cell, cfg, num_cells=2,
                             seeds_per_cell=2)
    for c in range(2):
        for s in range(2):
            inst = fl.run_federated_learning(
                ds, shards, cell, dataclasses.replace(cfg, seed=c * 2 + s))
            assert ([l.devices for l in grid[c][s].logs]
                    == [l.devices for l in inst.logs])
            np.testing.assert_array_equal(grid[c][s].accuracies(),
                                          inst.accuracies())
            np.testing.assert_array_equal(grid[c][s].times(), inst.times())


@pytest.mark.skipif(jax.local_device_count() < 2,
                    reason="needs >= 2 local devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count)")
def test_cell_sweep_sharded_matches_single_mesh(tiny_world):
    """shard_map over the cell mesh (including the C-padding path: C=3 on
    a 2-shard mesh) must equal the trivial 1-device-mesh program."""
    ds, cell, shards = tiny_world
    cfg = _cfg(m=4, group_size=2, rounds=3, horizon="scan")
    base = fl.run_cell_sweep(ds, shards, cell, cfg, num_cells=3,
                             seeds_per_cell=2)
    sharded = fl.run_cell_sweep(ds, shards, cell, cfg, num_cells=3,
                                seeds_per_cell=2, cell_shards=2)
    for c in range(3):
        for s in range(2):
            assert ([l.devices for l in base[c][s].logs]
                    == [l.devices for l in sharded[c][s].logs])
            np.testing.assert_array_equal(base[c][s].accuracies(),
                                          sharded[c][s].accuracies())


def _register_untraced_online():
    """A registered online policy WITHOUT the traced protocol — the
    rejection case since the built-in online policies all gained
    ``traced_protocol`` (PR 10).  Callers pop it in a finally block."""
    from repro.core import scheduling

    @scheduling.register_policy("test-untraced-online")
    class UntracedOnline(scheduling._ScoreTopKPolicy):
        traced_protocol = False

    return scheduling


def test_scan_accepts_traced_online_policies_at_config_time():
    """The built-in online policies carry the traced protocol, so
    horizon='scan' now accepts them (the equality grid in
    test_policy_scan.py pins the semantics)."""
    for name in ("update-aware", "age-fair", "matching-pursuit"):
        kw = (dict(uplink="ota", compression="none")
              if name == "matching-pursuit" else {})
        cfg = FLConfig(num_devices=4, group_size=2, num_rounds=2,
                       scheduler=name, horizon="scan", power_mode="max",
                       **kw)
        assert cfg.horizon == "scan"


def test_scan_rejects_untraced_online_policy_at_config_time():
    scheduling = _register_untraced_online()
    try:
        with pytest.raises(
            ValueError,
            match="horizon='scan' cannot drive online policy",
        ):
            FLConfig(num_devices=4, group_size=2, num_rounds=2,
                     scheduler="test-untraced-online", horizon="scan")
    finally:
        scheduling._REGISTRY.pop("test-untraced-online", None)


def test_scan_rejects_untraced_online_policy_called_directly(tiny_world):
    """run_horizon_scanned called with a per-round config must raise the
    same error rather than silently planning an offline schedule."""
    ds, cell, shards = tiny_world
    scheduling = _register_untraced_online()
    try:
        cfg = _cfg(m=4, group_size=2, rounds=2,
                   scheduler="test-untraced-online")
        with pytest.raises(
            ValueError,
            match="horizon='scan' cannot drive online policy",
        ):
            fl.run_horizon_scanned(ds, shards, cell, cfg)
    finally:
        scheduling._REGISTRY.pop("test-untraced-online", None)


def test_scan_online_rejects_mapel_at_config_time():
    """MAPEL's polyblock search is host-iterative: the traced round body
    cannot run it, so the scan + online + mapel combo is rejected up
    front with its own pinned message."""
    with pytest.raises(ValueError, match="cannot use power_mode='mapel'"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 scheduler="update-aware", power_mode="mapel",
                 horizon="scan")


def test_scan_online_rejects_mapel_called_directly(tiny_world):
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=2,
                   scheduler="update-aware", power_mode="mapel",
                   fl_engine="batched", seed=0)
    with pytest.raises(ValueError, match="cannot use power_mode='mapel'"):
        fl.run_horizon_scanned(ds, shards, cell, cfg)


def test_unknown_horizon_rejected_at_config_time():
    with pytest.raises(ValueError, match="unknown horizon"):
        FLConfig(num_devices=4, group_size=2, num_rounds=2,
                 horizon="time-travel")


# --------------------------------------------------------------------------
# Model-agnostic payloads through the scanned horizon
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def token_world():
    from repro.data.tokens import make_token_dataset

    ds = make_token_dataset(vocab_size=64, num_samples=400, seq_len=8,
                            seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.class_train, M, seed=0)
    return ds, cell, shards


def _model_cfg(*, horizon, model="tiny-transformer", topk=1.0, m=M,
               group_size=3, rounds=3):
    return FLConfig(num_devices=m, group_size=group_size, num_rounds=rounds,
                    learning_rate=0.05, batch_size=8,
                    scheduler="lazy-gwmin", power_mode="max",
                    compression="adaptive", fl_engine="batched",
                    horizon=horizon, model=model, topk=topk, seed=0)


@pytest.mark.parametrize("uplink", ["noma", "tdma"])
def test_scan_equality_grid_transformer(token_world, uplink):
    """scan vs per-round on a tiny registry transformer: identical
    schedules/bits/rates/ratios/times, bit-equal accuracies — the same
    contract the LeNet grid pins, now on a token payload."""
    per_round = _run(token_world, _model_cfg(horizon="per-round"),
                     uplink=uplink)
    scanned = _run(token_world, _model_cfg(horizon="scan"), uplink=uplink)
    _assert_equal_runs(per_round, scanned)


def test_scan_topk_matches_per_round(token_world):
    """The top-k ∘ DoReFa stage runs inside the scan body too: same traced
    (kept, bits) plans, same sparse on-air ratios, bit-equal accuracies."""
    per_round = _run(token_world, _model_cfg(horizon="per-round", topk=0.1))
    scanned = _run(token_world, _model_cfg(horizon="scan", topk=0.1))
    _assert_equal_runs(per_round, scanned)
    # the stage is actually on: ratios exceed the dense-at-these-bits value
    assert all(np.all(l.compression_ratios > 1.0)
               for l in scanned.logs if l.bits.size)


def test_transformer_class_payload_topk_batched_and_scan():
    """Acceptance pin: a >= 10^6-param transformer payload runs through
    BOTH the batched per-round engine and the scanned horizon with
    top-k + DoReFa, and the two agree bit for bit."""
    from repro.data.tokens import make_token_dataset
    from repro.models.fl_models import get_fl_model
    from repro.utils.tree import tree_count

    model = get_fl_model("tiny-transformer-1m")
    params = model.init(jax.random.PRNGKey(0))
    assert tree_count(params) >= 1_000_000

    ds = make_token_dataset(vocab_size=model.cfg.vocab_size,
                            num_samples=200, seq_len=8, seed=0)
    cell = channel.CellConfig(num_devices=6)
    shards = dirichlet_partition(ds.class_train, 6, seed=0)
    cfg = _model_cfg(horizon="per-round", model="tiny-transformer-1m",
                     topk=0.01, m=6, group_size=2, rounds=2)
    per_round = fl.run_federated_learning(ds, shards, cell, cfg)
    scanned = fl.run_federated_learning(
        ds, shards, cell, dataclasses.replace(cfg, horizon="scan"))
    _assert_equal_runs(per_round, scanned)
    # at 1% top-k the honest on-air ratio is large and the §IV clamp never
    # reports the meaningless dense r = 1
    assert all(np.all(l.compression_ratios > 5.0)
               for l in scanned.logs if l.bits.size)
