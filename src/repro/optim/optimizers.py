"""Optimizers in pure JAX (optax-style (init, update) pairs).

The framework builds its own optimizer substrate (no optax in this
container). ``update`` returns (new_params, new_state); learning-rate may be
a float or a schedule fn(step) -> float. All states are pytrees so they
shard/checkpoint like params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (params, state)


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"]
        eta = _lr_at(lr, step)
        new = jax.tree_util.tree_map(lambda p, g: p - eta * g.astype(p.dtype), params, grads)
        return new, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(jnp.zeros_like, params),
        }

    def update(grads, state, params):
        step, mu = state["step"], state["mu"]
        eta = _lr_at(lr, step)
        mu = jax.tree_util.tree_map(lambda m, g: beta * m + g.astype(m.dtype), mu, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: beta * m + g.astype(m.dtype), mu, grads)
        else:
            upd = mu
        new = jax.tree_util.tree_map(lambda p, u: p - eta * u.astype(p.dtype), params, upd)
        return new, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay):
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": z,
            "v": jax.tree_util.tree_map(jnp.zeros_like, z),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        eta = _lr_at(lr, step - 1)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def step_fn(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * upd).astype(p.dtype)

        new = jax.tree_util.tree_map(step_fn, params, m, v)
        return new, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr: Schedule, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: Schedule, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=weight_decay)
