"""Learning-rate schedules (callables of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1.0 - final_frac) * cos)

    return fn


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup_steps, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(step - warmup_steps))

    return fn
