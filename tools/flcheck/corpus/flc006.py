"""FLC006 corpus: pinned error messages must come from repro.core.errors.

Tests match on these messages (``pytest.raises(match=...)``) and several
modules raise them; a literal copy outside the constants module drifts
silently.  The duplication signatures are derived from the real
``src/repro/core/errors.py`` by parsing it.  Never executed — parsed only.
"""
from repro.core import errors


def bad_duplicated_literal(compression):
    if compression != "none":
        raise ValueError(  # expect: FLC006
            "uplink='ota' requires compression='none': the PS receives "
            "the noisy analog sum and never decodes per-device "
            "payloads, so DoReFa quantization cannot apply"
        )


def good_imported_constant(compression):
    if compression != "none":
        raise ValueError(errors.ERR_OTA_COMPRESSION)


def good_unpinned_message(x):
    if x < 0:
        raise ValueError(f"x must be non-negative, got {x}")
