"""Runtime sanitizers paired with the flcheck static pass.

Two guards, both grounded in bug classes the static rules cannot fully
close over:

* :func:`compile_count` — a context manager that counts XLA backend
  compiles via JAX's monitoring events.  FLC001 catches the *syntactic*
  recompile patterns (``jax.jit(bound_method)`` at call time); this guard
  catches the semantic ones: tier-1 tests wrap driver runs in it and
  assert the compile count is *constant* as round counts and seed counts
  scale (a per-round or per-seed retrace shows up as a linear count).

* :func:`nan_guard` — opt-in NaN sanitizer for the FL drivers.  Flips
  ``jax_debug_nans`` for the dynamic extent of the block (and restores the
  previous value on exit), so a NaN produced inside jitted FL math raises
  ``FloatingPointError`` at the offending primitive instead of silently
  poisoning accuracy curves.  Wired to ``--sanitize-nans`` in
  ``examples/fl_noma_mnist.py``.

Implementation note: ``jax.monitoring`` listeners are process-global and
cannot be unregistered individually (only wholesale via
``clear_event_listeners``, which would drop listeners we don't own), so a
single module-level listener is installed on first use and never removed;
the context manager reads deltas of its counter.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax

# every XLA backend_compile lands exactly one of these duration events
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class _CompileCounter:
    """Process-global tally of backend-compile monitoring events."""

    def __init__(self):
        self.lock = threading.Lock()
        self.total = 0
        self.installed = False

    def _listen(self, event: str, duration: float, **kwargs) -> None:
        if event == COMPILE_EVENT:
            with self.lock:
                self.total += 1

    def install(self) -> None:
        with self.lock:
            if self.installed:
                return
            self.installed = True
        jax.monitoring.register_event_duration_secs_listener(self._listen)

    def snapshot(self) -> int:
        with self.lock:
            return self.total


_COUNTER = _CompileCounter()


@dataclasses.dataclass
class CompileTally:
    """Result handle yielded by :func:`compile_count`.

    ``count`` is None inside the block and the number of XLA backend
    compiles that occurred within it after the block exits.
    """
    count: "int | None" = None


@contextlib.contextmanager
def compile_count():
    """Count XLA backend compiles inside the block.

    >>> with compile_count() as tally:
    ...     run_horizon_scanned(...)
    >>> assert tally.count == expected

    Counts are process-wide, not thread-scoped: compiles triggered by
    other threads during the block are attributed to it.  Tests that
    assert exact counts should warm up incidental constants (e.g. a run
    at a *different* static shape) first, so the counted blocks compile
    the same set of fresh programs.
    """
    _COUNTER.install()
    tally = CompileTally()
    start = _COUNTER.snapshot()
    try:
        yield tally
    finally:
        tally.count = _COUNTER.snapshot() - start


@contextlib.contextmanager
def nan_guard(enable: bool = True):
    """Opt-in NaN sanitizer: ``jax_debug_nans`` for this dynamic extent.

    Under the guard, a NaN output from any jitted primitive re-runs
    un-jitted and raises ``FloatingPointError`` at the source.  This
    de-optimizes (per-primitive checks + possible retraces), so it is a
    debugging mode, never a default.  The previous setting is restored
    even if the block raises.
    """
    prev = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", bool(enable))
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", prev)
