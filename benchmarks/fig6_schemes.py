"""Paper Fig. 6: testing accuracy vs rounds for the four scheduling/power
schemes:
  1. optimal (MWIS) scheduling + MAPEL power allocation   (proposed)
  2. optimal scheduling + max power
  3. random scheduling + MAPEL power allocation
  4. random scheduling + max power

Paper claim: scheme 1 dominates throughout; schemes 1-3 exceed ~60% at T=35;
scheme 4 is the weakest. We validate the ORDERING (1 best, 4 worst) on the
synthetic set.

Beyond the paper's four, the sweep carries the ref [6] baselines and the
online FL-state-aware policies (update-aware: Amiri et al. arXiv:2001.10402;
age-fair: Yang et al. arXiv:1908.06287; matching-pursuit: the OTA companion
policy of repro.core.ota, which at ota_noise=0 greedily admits by weighted
update energy), all running live inside the training loop — every curve
goes through the same policy registry."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import World, build_world, emit
from repro.config import FLConfig
from repro.core import fl

SCHEMES = [
    ("opt_sched+opt_power", "lazy-gwmin", "mapel"),
    ("opt_sched+max_power", "lazy-gwmin", "max"),
    ("rand_sched+opt_power", "random", "mapel"),
    ("rand_sched+max_power", "random", "max"),
    # ref [6] policies for context (beyond the paper's four)
    ("round_robin+max_power", "round-robin", "max"),
    ("prop_fair+max_power", "proportional-fair", "max"),
    # online FL-state-aware policies (live select_round inside the FL loop)
    ("update_aware+max_power", "update-aware", "max"),
    ("age_fair+max_power", "age-fair", "max"),
    ("matching_pursuit+max_power", "matching-pursuit", "max"),
]


def main(fast: bool = False):
    world = build_world(num_devices=60 if fast else 150,
                        num_samples=3000 if fast else 6000)
    rounds = 8 if fast else 20
    finals = {}
    curves = {}
    t0 = time.perf_counter()
    for name, sched, power in SCHEMES:
        cfg = FLConfig(num_devices=world.cell.num_devices, group_size=3,
                       num_rounds=rounds, scheduler=sched, power_mode=power,
                       compression="adaptive", seed=0)
        res = fl.run_federated_learning(world.dataset, world.shards,
                                        world.cell, cfg, uplink="noma")
        finals[name] = res.accuracies()[-1]
        curves[name] = res.accuracies()
    us = (time.perf_counter() - t0) * 1e6
    for name, acc in finals.items():
        emit(f"fig6.{name}", us / len(SCHEMES), f"{acc:.3f}")
    # mean-over-rounds captures "consistently best" better than the endpoint
    means = {k: float(np.mean(v)) for k, v in curves.items()}
    emit("fig6.proposed_mean_acc", us / len(SCHEMES),
         f"{means['opt_sched+opt_power']:.3f}")
    best = max(means, key=means.get)
    emit("fig6.best_scheme", us / len(SCHEMES), best)
    return curves


if __name__ == "__main__":
    main()
