"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke] [--only NAME]

``--smoke`` is the CI mode: implies ``--fast`` and skips the FL-training
suites (fig5/fig6) plus the roofline sweep, so the job finishes in minutes
while still exercising the power, scheduling, kernel, and compression paths.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
The scheduling and fl_engine suites additionally return sweep records that
are persisted at the repo root (``BENCH_scheduling.json``: M sweep x
numpy/jax scheduler backend; ``BENCH_fl.json``: K x M round-loop sweep,
legacy vs batched FL engine) so both perf trajectories are tracked from
PR to PR.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback

SUITES = [
    ("power", "benchmarks.power_bench"),           # §III-C / ref [8]
    ("scheduling", "benchmarks.scheduling_bench"), # §III-A/B Algorithm 2
    ("kernels", "benchmarks.kernel_bench"),        # §II-B codec hot-spot
    ("compression", "benchmarks.compression_stats"),  # §II-B adaptive bits
    ("fl_engine", "benchmarks.fl_bench"),          # legacy vs batched round loop
    ("fig5", "benchmarks.fig5_noma_vs_tdma"),      # Fig. 5
    ("fig6", "benchmarks.fig6_schemes"),           # Fig. 6
    ("roofline", "benchmarks.roofline_bench"),     # EXPERIMENTS §Roofline
]

# FL-training suites (minutes even at --fast) and the roofline sweep are out
# of scope for the CI smoke job.  fl_engine stays in: its --fast case is one
# tiny cell (M=60, 4 rounds) and it is the smoke signal for the batched
# round engine regressing against the legacy oracle's wall-clock.
SMOKE_SKIP = {"fig5", "fig6", "roofline"}

# Suites whose main() returns a dict of records persisted at the repo root
# (suffixed _fast under --fast/--smoke so the tracked full-sweep record is
# never clobbered by a small run).
PERSIST = {"scheduling": "BENCH_scheduling", "fl_engine": "BENCH_fl"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: --fast minus the FL-training suites")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    fast = args.fast or args.smoke

    import importlib

    failures = []
    for name, module in SUITES:
        if args.only and args.only != name:
            continue
        if args.smoke and name in SMOKE_SKIP and args.only != name:
            continue
        print(f"# === {name} ({module}) ===", flush=True)
        try:
            result = importlib.import_module(module).main(fast=fast)
            if name in PERSIST and isinstance(result, dict):
                suffix = "_fast" if fast else ""
                out = pathlib.Path(__file__).resolve().parent.parent / (
                    f"{PERSIST[name]}{suffix}.json"
                )
                out.write_text(json.dumps(result, indent=2) + "\n")
                print(f"# wrote {out}", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}")
        sys.exit(1)
    print("# all suites ok")


if __name__ == "__main__":
    main()
