"""Self-test corpus runner.

Each ``corpus/flcNNN.py`` file carries minimal positive and negative
snippets for one rule.  Positive lines end with ``# expect: FLCxxx``
(comma-separated for several rules on one line); every other line must
stay silent.  ``run_selftest`` checks the *exact* set of (line, rule)
diagnostics per file against the markers — a rule that under-fires
(missed positive) or over-fires (phantom on a negative) both fail.

FLC006 needs the pinned-message fragments, which are derived from the
real ``src/repro/core/errors.py`` next to this checkout.
"""
from __future__ import annotations

import os
import re

from tools.flcheck.checker import (
    RULES, check_paths, find_errors_module, pinned_fragments,
)

_CORPUS = os.path.join(os.path.dirname(__file__), "corpus")
_EXPECT_RE = re.compile(r"#\s*expect:\s*(?P<rules>FLC[0-9]{3}(?:\s*,\s*FLC[0-9]{3})*)")


def _expected(path: str) -> set:
    out = set()
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            m = _EXPECT_RE.search(line)
            if m:
                for rule in m.group("rules").split(","):
                    out.add((lineno, rule.strip()))
    return out


def run_selftest(corpus_dir: str = _CORPUS) -> list:
    """Returns a list of human-readable failure strings (empty == pass)."""
    repo_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    errors_path = find_errors_module([os.path.join(repo_root, "src"), "src"])
    fragments = pinned_fragments(errors_path) if errors_path else {}

    failures: list = []
    files = sorted(
        os.path.join(corpus_dir, f)
        for f in os.listdir(corpus_dir)
        if f.endswith(".py") and f != "__init__.py"
    )
    if not files:
        return [f"selftest: empty corpus at {corpus_dir}"]

    covered = set()
    for path in files:
        expected = _expected(path)
        actual = {
            (d.line, d.rule)
            for d in check_paths(
                [path],
                search_dirs=(os.path.join(repo_root, "src"), "src", "."),
                fragments=fragments,
            )
        }
        covered |= {r for _, r in expected}
        for line, rule in sorted(expected - actual):
            failures.append(
                f"{path}:{line} expected {rule} but the checker was silent"
            )
        for line, rule in sorted(actual - expected):
            failures.append(
                f"{path}:{line} unexpected {rule} (negative snippet fired)"
            )

    missing = sorted(set(RULES) - covered)
    if missing:
        failures.append(
            f"corpus has no positive snippet for: {', '.join(missing)}"
        )
    return failures
