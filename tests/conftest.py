# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real CPU device; only launch/dryrun.py (a
# __main__ entry point, never imported by tests) forces 512 devices.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
