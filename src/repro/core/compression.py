"""Gradient pytree codec built on the DoReFa quantizer (paper Algorithm 1).

This is the layer the distributed trainer calls: it measures the payload,
derives the adaptive bit-width from the device's NOMA bit budget, and
quantize-dequantizes the whole gradient pytree (simulating the uplink).

``encode_decode_tree`` is the fused q->dq used inside jitted train steps (no
packing — XLA fuses it into the backward epilogue). ``encode_tree`` /
``decode_tree`` produce the packed integer representation used by the
paper-scale FL simulator for honest byte accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as q
from repro.kernels import ops as kops


def payload_bits(tree, *, full_bits: int = 32) -> int:
    """Uncompressed payload size I in bits (paper: 32 bits/param).

    Pure Python-int arithmetic end to end: a 10^8-param tree at 32 bits
    (~3.2e9) exceeds int32, so the count must never round-trip through a
    32-bit dtype — downstream jnp consumers coerce through float
    (``quantization._host_scalar_to_float``) instead of int.
    """
    return sum(int(x.size) * full_bits for x in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Top-k sparsification: a composable stage BEFORE DoReFa quantization.
#
# The §IV bit budget c_k = R_k * B * t that drives adaptive_bits_for_budget
# also prices a sparse payload.  On-air encoding per kept coordinate: a
# sign-magnitude DoReFa code (b+1 bits) plus a coordinate index
# (ceil(log2 P) bits), plus one fp32 scale per client:
#
#     S_k = k_k * (b_k + 1 + idx_bits) + 32        (sparse on-air bits)
#
# The (k, b) split spends the budget on coverage first: k_k is the largest
# kept count affordable at the 1-bit floor (b+1+idx = 2+idx bits/coord),
# capped by the FLConfig.topk fraction, and the leftover per-coordinate
# budget becomes the DoReFa width b_k.  Both are traced per client, exactly
# like the dense adaptive bits.  Round timing stays slot-based (the paper's
# Fig. 5 axis): sparsification changes what crosses the slot, not the slot
# itself — the honest ratio I / S_k is logged alongside.
# ---------------------------------------------------------------------------


def topk_index_bits(num_params: int) -> int:
    """Bits to address one coordinate of a P-param payload: ceil(log2 P)."""
    if num_params < 1:
        raise ValueError(f"num_params must be >= 1, got {num_params}")
    return max(1, int(np.ceil(np.log2(num_params))))


def topk_plan(num_params: int, budget_bits, *, topk: float = 1.0):
    """Traced per-client (kept, bits) from the §IV budgets (paper Eq. 7 ext).

    ``budget_bits``: (K,) traced or concrete slot budgets c_k.  Returns
    ``(kept, bits)`` int32 (K,) vectors: kept coordinates k_k in
    [1, ceil(topk * P)] and DoReFa width b_k in [1, 32].  Host ints stay
    Python-int until the final float coercion (no int32 round-trip).
    """
    idx = topk_index_bits(num_params)
    k_cap = max(1, int(np.ceil(topk * num_params)))
    c = jnp.asarray(budget_bits, jnp.float32)
    spend = jnp.maximum(c - 32.0, 0.0)  # fp32 scale off the top
    kept = jnp.clip(
        jnp.floor(spend / float(2 + idx)), 1.0, float(k_cap)
    ).astype(jnp.int32)
    bits = jnp.clip(
        jnp.floor(spend / kept.astype(jnp.float32)) - float(1 + idx),
        1.0, 32.0,
    ).astype(jnp.int32)
    return kept, bits


def topk_mask(flat: jax.Array, kept) -> jax.Array:
    """(K, N) magnitude top-k mask with traced per-row k (exact count).

    Double-argsort ranks: ``ranks[i, j]`` is the magnitude rank of
    coordinate j in row i (0 = largest; ties broken by position,
    deterministically), and the mask keeps ranks < kept[i].  Supports the
    edges kept=0 (all-zero row) and kept=N (identity).
    """
    order = jnp.argsort(-jnp.abs(flat), axis=1)
    ranks = jnp.argsort(order, axis=1)
    kept_col = jnp.asarray(kept, jnp.int32).reshape(-1, 1)
    return (ranks < kept_col).astype(flat.dtype)


def sparse_payload_bits(kept, bits, num_params: int):
    """Honest on-air size S_k of a top-k + DoReFa payload (float64)."""
    idx = topk_index_bits(num_params)
    kept = np.asarray(kept, np.float64)
    bits = np.asarray(bits, np.float64)
    return kept * (bits + 1.0 + idx) + 32.0


def sparse_compression_ratio(payload_bits_, kept, bits, num_params: int):
    """r = max(I / S_k, 1) for the sparse payload (float64, host-side)."""
    on_air = sparse_payload_bits(kept, bits, num_params)
    return np.maximum(float(payload_bits_) / np.maximum(on_air, 1e-9), 1.0)


@dataclasses.dataclass
class EncodedTree:
    """Packed quantized gradient pytree (what actually crosses the uplink)."""

    codes: Any              # pytree of int arrays (packed)
    scales: Any             # pytree of fp32 scalars
    bits: int
    treedef: Any
    shapes: list
    total_bits: int         # honest on-air size, incl. per-tensor scales


def encode_tree(tree, bits: int, *, use_pallas: bool = False) -> EncodedTree:
    """Quantize + bit-pack every leaf. Static ``bits``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    codes, scales, shapes = [], [], []
    total = 0
    for leaf in leaves:
        c, s = kops.quantize_pack(leaf.reshape(-1), bits, use_pallas=use_pallas)
        codes.append(c)
        scales.append(s)
        shapes.append(leaf.shape)
        # b+1 bits per element (sign-magnitude code) + one fp32 scale.
        total += leaf.size * (bits + 1) + 32
    return EncodedTree(codes, scales, bits, treedef, shapes, total)


def decode_tree(enc: EncodedTree, *, use_pallas: bool = False):
    leaves = []
    for c, s, shape in zip(enc.codes, enc.scales, enc.shapes):
        size = int(np.prod(shape)) if shape else 1
        x = kops.unpack_dequantize(c, s, enc.bits, size, use_pallas=use_pallas)
        leaves.append(x.reshape(shape))
    return jax.tree_util.tree_unflatten(enc.treedef, leaves)


def encode_decode_tree(tree, bits, *, paper_exact: bool = False):
    """Fused quantize->dequantize of a pytree (traceable).

    ``bits`` may be a traced scalar, or a (K,) vector (traced or not) when
    every leaf carries a leading client axis of length K — the batched FL
    engine quantizes all K scheduled clients' deltas to their own adaptive
    bit-widths in one dispatch this way (see ``quantization.quantize_tree``).
    """
    return q.quantize_tree(tree, bits, paper_exact=paper_exact)


def adaptive_bits_for_budget(tree, budget_bits) -> jax.Array:
    """Paper §II-B: b = floor(32/r), r = max(I/c, 1)."""
    return q.adaptive_bits(payload_bits(tree), budget_bits)


def error_feedback_optimizer(optimizer, bits: int, *, paper_exact: bool = False):
    """BEYOND-PAPER: error-feedback (EF) wrapper around any optimizer.

    Plain DoReFa quantization (paper Eq. 7) discards the rounding residual
    every round; EF [Seide et al. 2014; Karimireddy et al. 2019] adds the
    previous round's residual back before quantizing, making the compressed
    update unbiased over time:

        adj_t = g_t + r_{t-1};  q_t = Q_b(adj_t);  r_t = adj_t - q_t.

    At paper scale C1 (each device scheduled once) makes per-device EF moot;
    at LLM scale (one quantized uplink per optimizer step) it recovers most
    of the accuracy lost at b <= 4 bits (see examples/train_llm.py --ef and
    tests/test_compression.py::test_error_feedback_identity).
    """
    from repro.optim.optimizers import Optimizer

    def init(params):
        return {
            "inner": optimizer.init(params),
            "residual": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        adj = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, state["residual"])
        q = encode_decode_tree(adj, bits, paper_exact=paper_exact)
        residual = jax.tree_util.tree_map(lambda a, qq: a - qq, adj, q)
        new_params, inner = optimizer.update(q, state["inner"], params)
        return new_params, {"inner": inner, "residual": residual}

    return Optimizer(init, update)
