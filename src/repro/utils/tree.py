"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_flatten_with_paths(tree):
    """Yield (path_string, leaf) pairs with '/'-joined key paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out
