"""Gradient pytree codec built on the DoReFa quantizer (paper Algorithm 1).

This is the layer the distributed trainer calls: it measures the payload,
derives the adaptive bit-width from the device's NOMA bit budget, and
quantize-dequantizes the whole gradient pytree (simulating the uplink).

``encode_decode_tree`` is the fused q->dq used inside jitted train steps (no
packing — XLA fuses it into the backward epilogue). ``encode_tree`` /
``decode_tree`` produce the packed integer representation used by the
paper-scale FL simulator for honest byte accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as q
from repro.kernels import ops as kops


def payload_bits(tree, *, full_bits: int = 32) -> int:
    """Uncompressed payload size I in bits (paper: 32 bits/param)."""
    return sum(int(x.size) * full_bits for x in jax.tree_util.tree_leaves(tree))


@dataclasses.dataclass
class EncodedTree:
    """Packed quantized gradient pytree (what actually crosses the uplink)."""

    codes: Any              # pytree of int arrays (packed)
    scales: Any             # pytree of fp32 scalars
    bits: int
    treedef: Any
    shapes: list
    total_bits: int         # honest on-air size, incl. per-tensor scales


def encode_tree(tree, bits: int, *, use_pallas: bool = False) -> EncodedTree:
    """Quantize + bit-pack every leaf. Static ``bits``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    codes, scales, shapes = [], [], []
    total = 0
    for leaf in leaves:
        c, s = kops.quantize_pack(leaf.reshape(-1), bits, use_pallas=use_pallas)
        codes.append(c)
        scales.append(s)
        shapes.append(leaf.shape)
        # b+1 bits per element (sign-magnitude code) + one fp32 scale.
        total += leaf.size * (bits + 1) + 32
    return EncodedTree(codes, scales, bits, treedef, shapes, total)


def decode_tree(enc: EncodedTree, *, use_pallas: bool = False):
    leaves = []
    for c, s, shape in zip(enc.codes, enc.scales, enc.shapes):
        size = int(np.prod(shape)) if shape else 1
        x = kops.unpack_dequantize(c, s, enc.bits, size, use_pallas=use_pallas)
        leaves.append(x.reshape(shape))
    return jax.tree_util.tree_unflatten(enc.treedef, leaves)


def encode_decode_tree(tree, bits, *, paper_exact: bool = False):
    """Fused quantize->dequantize of a pytree (traceable).

    ``bits`` may be a traced scalar, or a (K,) vector (traced or not) when
    every leaf carries a leading client axis of length K — the batched FL
    engine quantizes all K scheduled clients' deltas to their own adaptive
    bit-widths in one dispatch this way (see ``quantization.quantize_tree``).
    """
    return q.quantize_tree(tree, bits, paper_exact=paper_exact)


def adaptive_bits_for_budget(tree, budget_bits) -> jax.Array:
    """Paper §II-B: b = floor(32/r), r = max(I/c, 1)."""
    return q.adaptive_bits(payload_bits(tree), budget_bits)


def error_feedback_optimizer(optimizer, bits: int, *, paper_exact: bool = False):
    """BEYOND-PAPER: error-feedback (EF) wrapper around any optimizer.

    Plain DoReFa quantization (paper Eq. 7) discards the rounding residual
    every round; EF [Seide et al. 2014; Karimireddy et al. 2019] adds the
    previous round's residual back before quantizing, making the compressed
    update unbiased over time:

        adj_t = g_t + r_{t-1};  q_t = Q_b(adj_t);  r_t = adj_t - q_t.

    At paper scale C1 (each device scheduled once) makes per-device EF moot;
    at LLM scale (one quantized uplink per optimizer step) it recovers most
    of the accuracy lost at b <= 4 bits (see examples/train_llm.py --ef and
    tests/test_compression.py::test_error_feedback_identity).
    """
    from repro.optim.optimizers import Optimizer

    def init(params):
        return {
            "inner": optimizer.init(params),
            "residual": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        adj = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, state["residual"])
        q = encode_decode_tree(adj, bits, paper_exact=paper_exact)
        residual = jax.tree_util.tree_map(lambda a, qq: a - qq, adj, q)
        new_params, inner = optimizer.update(q, state["inner"], params)
        return new_params, {"inner": inner, "residual": residual}

    return Optimizer(init, update)
