"""FL round-engine benchmark: legacy per-device loop vs batched engine.

Measures the steady-state **round-loop** time of ``fl.run_federated_learning``
(median per-round wall time from the progress callbacks, so setup —
channel sampling, scheduling, ClientBank build, jit compilation — is
excluded) for ``fl_engine in {legacy, batched}`` over the K x M sweep the
batched engine exists for.  ``benchmarks/run.py`` persists the records to
``BENCH_fl.json`` (``BENCH_fl_fast.json`` under --fast/--smoke) so the
round-loop speedup is tracked from PR to PR.

Settings: round-robin scheduling (cheap, deterministic, K devices every
round), max power, adaptive compression, NOMA uplink — the round body is
the only thing that differs between the two engines.
"""
from __future__ import annotations

import dataclasses
import gc
import time

import numpy as np

from benchmarks.common import emit
from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like


def _per_round_seconds(ds, shards, cell, cfg, *, passes: int = 2):
    """Median steady-state round time: warm-compile run, then measure the
    deltas between progress callbacks (covers rounds 1..R-1; setup and the
    round-0 tail of compilation land before the first delta).  Best of
    ``passes`` timed runs, so a background hiccup in one pass does not
    poison the record."""
    fl.run_federated_learning(ds, shards, cell, cfg, eval_every=10**9)
    best = np.inf
    for _ in range(passes):
        ts = []
        fl.run_federated_learning(
            ds, shards, cell, cfg, eval_every=10**9,
            progress=lambda log: ts.append(time.perf_counter()),
        )
        best = min(best, float(np.median(np.diff(ts))))
    return best


def main(fast: bool = False) -> dict:
    if fast:
        cases = [(60, 3)]
        rounds, samples = 4, 1500
    else:
        cases = [(m, k) for m in (300, 1000) for k in (3, 8, 16)]
        rounds, samples = 6, 12_000
    records = []
    for m, k in cases:
        gc.collect()   # drop the previous case's dataset + ClientBank now
        ds = make_mnist_like(num_samples=samples, seed=0)
        cell = channel.CellConfig(num_devices=m)
        shards = dirichlet_partition(ds.y_train, m, seed=0)
        cfg = FLConfig(
            num_devices=m, group_size=k, num_rounds=rounds,
            scheduler="round-robin", power_mode="max",
            compression="adaptive", seed=0,
        )
        legacy_s = _per_round_seconds(ds, shards, cell, cfg)
        batched_s = _per_round_seconds(
            ds, shards, cell, dataclasses.replace(cfg, fl_engine="batched")
        )
        speedup = legacy_s / batched_s
        records.append({
            "m": m, "k": k, "rounds": rounds,
            "legacy_s_per_round": legacy_s,
            "batched_s_per_round": batched_s,
            "speedup": round(speedup, 2),
        })
        emit(f"fl.round_legacy_M{m}_K{k}", legacy_s * 1e6)
        emit(f"fl.round_batched_M{m}_K{k}", batched_s * 1e6,
             f"speedup {speedup:.1f}x")
    return {
        "suite": "fl_engine_round_loop",
        "settings": {
            "scheduler": "round-robin", "power_mode": "max",
            "compression": "adaptive", "uplink": "noma",
            "rounds": rounds, "num_samples": samples,
        },
        "records": records,
    }


if __name__ == "__main__":
    main()
