"""Mixtral-8x22B: 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    num_experts=8, experts_per_token=2,
    sliding_window=4096, rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64,
    num_experts=4, experts_per_token=2, sliding_window=64,
    source="reduced mixtral family",
)
