"""Logical-axis -> PartitionSpec translation (DESIGN.md §5).

Params carry logical axis names (repro.models.params). Physical mapping:

    embed   -> "data"   (FSDP: weights reduce-scattered over the data axis)
    mlp     -> "model"  (tensor parallel: d_ff, d_inner)
    heads   -> "model"  (tensor parallel: attention / SSM heads)
    vocab   -> "model"
    expert  -> "model"  (expert parallel, when num_experts divides the axis)
    kv / layers / expert_in / None -> replicated

Safety valves, applied per-tensor and in order:
  1. a physical axis is used at most once per tensor (first dim wins);
  2. a dim not divisible by the axis size falls back to replicated
     (e.g. mixtral's 8 experts on a 16-way model axis -> experts
     replicated, d_ff sharded instead — exactly the 2D layout DESIGN.md
     prescribes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    table: dict

    def physical(self, logical: Optional[str]):
        return self.table.get(logical)


DEFAULT_RULES = AxisRules(
    {
        "embed": "data",
        "mlp": "model",
        "heads": "model",
        "vocab": "model",
        "expert": "model",
    }
)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 0


def translate(axes, shape, mesh: Mesh, rules: AxisRules = DEFAULT_RULES) -> P:
    """Logical axes tuple (len == ndim) -> PartitionSpec for this mesh.

    Embedding/unembedding tensors (any tensor with a "vocab" axis) shard
    only the vocab dim: FSDP-sharding their "embed" dim puts the unembed
    contraction over a sharded dim and SPMD inserts a (B, S, V) fp32
    partial-sum all-reduce — measured at 38 GiB per occurrence on
    qwen2-0.5b/train_4k (EXPERIMENTS.md §Perf iteration 0)."""
    used = set()
    out = []
    vocab_tensor = "vocab" in axes
    for dim, logical in zip(shape, axes):
        phys = rules.physical(logical)
        if vocab_tensor and logical == "embed":
            phys = None
        if (
            phys is None
            or phys in used
            or phys not in mesh.shape
            or dim % _axis_size(mesh, phys) != 0
        ):
            out.append(None)
        else:
            out.append(phys)
            used.add(phys)
    return P(*out)


def param_pspecs(logical_tree, abstract_tree, mesh: Mesh,
                 rules: AxisRules = DEFAULT_RULES):
    """Pytree of PartitionSpec matching the parameter pytree."""
    return jax.tree_util.tree_map(
        lambda axes, ab: translate(axes, ab.shape, mesh, rules),
        logical_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def batch_axes(mesh: Mesh):
    """Physical axes carrying the batch dim: ("pod","data") when multi-pod."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_shard(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def activation_specs(mesh: Mesh, batch: int, *, extra_dims: int = 1) -> P:
    """Spec for (B, S, ...) activations/token batches."""
    ba = batch_axes(mesh)
    if batch % batch_shard(mesh) == 0:
        return P(ba, *([None] * extra_dims))
    return P(*([None] * (1 + extra_dims)))


def cache_pspec(mesh: Mesh, cache_shape, *, stacked_dims: int = 1) -> P:
    """Spec for a stacked KV cache (L..., B, S, H, D).

    Prefers batch -> (pod?,data), heads -> model. When batch is too small
    (long_500k: B=1) the *sequence* dim shards over the data axes instead
    (flash-decode layout; softmax reduction collectives inserted by SPMD).
    """
    lead = [None] * stacked_dims
    b, s, h, d = cache_shape[stacked_dims:]
    ba = batch_axes(mesh)
    model_ok = "model" in mesh.shape and h % mesh.shape["model"] == 0
    hspec = "model" if model_ok else None
    if b % batch_shard(mesh) == 0:
        return P(*lead, ba, None, hspec, None)
    if s % batch_shard(mesh) == 0:
        return P(*lead, None, ba, hspec, None)
    return P(*lead, None, None, hspec, None)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------------
# FL simulator cell axis (the scheduler's vertex-mesh sibling)
# --------------------------------------------------------------------------

CELL_AXIS = "cell"
# The multi-cell FL sweep's mesh axis (repro.launch.mesh.cell_mesh): whole
# independent simulations — (params, schedule tensors, eval plans) stacked
# (C, S, ...) — are sharded over it; cells never communicate.


def cell_sweep_in_specs() -> tuple:
    """in_specs for the shard_map'd cell sweep (fl_engine.run_horizon_sharded).

    Positional contract: (params_cs, dev, budgets, agg_w, gains, noise_keys,
    eval_mask, eval_idx, xb, yb, xe, ye) — per-instance stacks (including
    the OTA channel gains and receiver-noise keys) shard their leading cell
    axis; the eval cadence mask, the client bank, and the test set are
    replicated.
    """
    c = P(CELL_AXIS)
    r = P()
    return (c, c, c, c, c, c, r, c, r, r, r, r)


def cell_sweep_out_specs() -> tuple:
    """out_specs: (final params, bits, kept, accuracies), all cell-stacked."""
    c = P(CELL_AXIS)
    return (c, c, c, c)


def cell_sweep_online_in_specs() -> tuple:
    """in_specs for the online-policy cell sweep
    (fl_engine.run_horizon_online_sharded).

    Positional contract: (params_cs, solo, gains, noise_keys, eval_mask,
    eval_idx, weights_m, sizes_m, xb, yb, xe, ye) — per-instance stacks
    (model inits, solo-rate tables, channel rows, noise keys, eval plans)
    shard their leading cell axis; the eval cadence mask, the shared data
    weights/sizes, the client bank and the test set are replicated.
    """
    c = P(CELL_AXIS)
    r = P()
    return (c, c, c, c, r, c, r, r, r, r, r, r)


def cell_sweep_online_out_specs() -> tuple:
    """out_specs: (final params, device ids, validity masks, bits, kept,
    accuracies), all cell-stacked."""
    c = P(CELL_AXIS)
    return (c, c, c, c, c, c)
