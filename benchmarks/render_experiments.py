"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the JSONL
artifacts in results/. Usage:

    PYTHONPATH=src python -m benchmarks.render_experiments > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f]


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(rows, title):
    out = [f"### {title}", "",
           "| arch | shape | status | compile s | mem/dev GiB | notes |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        note = r.get("error", "")[:60] if r["status"] != "OK" else ""
        mem = fmt_bytes(r["bytes_per_device"]) if r["status"] == "OK" else "-"
        cs = f"{r['compile_s']:.0f}" if r["status"] == "OK" else "-"
        out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | {cs} | "
                   f"{mem} | {note} |")
    ok = sum(r["status"] == "OK" for r in rows)
    fail = sum(r["status"] == "FAIL" for r in rows)
    skip = sum(r["status"] == "SKIP" for r in rows)
    out += ["", f"**{ok} OK / {fail} FAIL / {skip} SKIP**", ""]
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck | "
           "useful | AG GiB | AR GiB | RS GiB | A2A GiB |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                       f"{r.get('error','')[:40]} | | | | | | | | |")
            continue
        s = r["roofline"]
        cb = s["collective_breakdown"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {s['t_compute_s']*1e3:.2f} | "
            f"{s['t_memory_s']*1e3:.2f} | {s['t_collective_s']*1e3:.2f} | "
            f"**{s['bottleneck']}** | {s['useful_flops_ratio']:.2f} | "
            f"{cb.get('all-gather',0)/2**30:.2f} | "
            f"{cb.get('all-reduce',0)/2**30:.2f} | "
            f"{cb.get('reduce-scatter',0)/2**30:.2f} | "
            f"{cb.get('all-to-all',0)/2**30:.2f} |")
    return "\n".join(out)


def main(fast: bool = False):
    sp = load("dryrun_single_pod.jsonl")
    mp = load("dryrun_multi_pod.jsonl")
    rf = load("roofline.jsonl")
    if sp:
        print(dryrun_table(sp, "Single-pod mesh (data=16, model=16) = 256 chips"))
    if mp:
        print(dryrun_table(mp, "Multi-pod mesh (pod=2, data=16, model=16) = 512 chips"))
    if rf:
        print("### Roofline (single-pod, depth-extrapolated, per-chip seconds)\n")
        print(roofline_table(rf))


if __name__ == "__main__":
    main()
