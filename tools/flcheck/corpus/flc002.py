"""FLC002 corpus: builtin hash()/id() in seed / registry paths.

The PR 8 bug: ``hash(keystr(path))`` folded into per-leaf init seeds is
salted by PYTHONHASHSEED, so model init differed across processes.  Fixed
with ``zlib.crc32`` of a stable encoding.  Never executed — parsed only.
"""
import zlib


def bad_seed_from_hash(path_str, base_seed):
    return base_seed + hash(path_str) % (2 ** 31)  # expect: FLC002


def bad_registry_key(obj):
    return id(obj)  # expect: FLC002


def good_crc32_fold(path_str, base_seed):
    return base_seed + zlib.crc32(path_str.encode()) % (2 ** 31)


def good_suppressed(path_str):
    # a deliberate, reviewed use keeps working under suppression
    return hash(path_str)  # flcheck: disable=FLC002
