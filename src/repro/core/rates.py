"""Batched SIC rate engine (paper Eq. 2-4) — the shared hot path.

Every scheduler in this repo scores candidate NOMA groups by their weighted
sum rate under successive interference cancellation.  The math is identical
everywhere (decode in descending receive-power order; each device's SINR sees
only the not-yet-decoded tail as interference), so it lives here once and the
schedulers call it on a whole (V, K) batch of candidate groups at a time
instead of once per ``itertools.combinations`` subset:

    R_k = log2(1 + p_k h_k^2 / (sum_{j decoded after k} p_j h_j^2 + sigma^2))

``sic_rates`` broadcasts over arbitrary leading axes; ``batched_weighted_rates``
is the (V, K) -> (V,) scorer the MWIS schedulers use.  Ties in receive power
are broken by input index (stable sort), matching the accelerator path in
``repro.kernels.sic_rates`` bit-for-bit so numpy and Pallas agree on the
argmax subset.

Accelerator path: ``repro.core.rates_jax`` is the jnp mirror of this module
(same stable tie-break, same shifted-suffix-sum interference tail) used by
the device-resident MWIS greedy (``scheduling.lazy_greedy_schedule``
``backend="jax"``) to score a whole (T, V, K) vertex tensor per greedy step,
and by ``repro.kernels.ops.sic_weighted_rates`` (with a Pallas kernel behind
``use_pallas=True``).  The numpy path here is the control-plane default —
scheduling batches are O(10^4) vertices and the engine is called from inside
Python greedy loops.
"""
from __future__ import annotations

import numpy as np


def sic_rates(powers, gains, noise_power: float) -> np.ndarray:
    """Per-device SIC spectral efficiencies, input order.

    powers, gains: (..., K) arrays (any matching leading batch axes).
    Returns (..., K) rates with decode order = descending receive power,
    ties broken by lower input index first (stable sort).
    """
    p = np.asarray(powers, dtype=np.float64)
    g = np.asarray(gains, dtype=np.float64)
    rx = p * g * g
    order = np.argsort(-rx, axis=-1, kind="stable")
    rx_s = np.take_along_axis(rx, order, axis=-1)
    # Suffix sum over the decode axis: interference seen by sorted pos i is
    # the sum of receive powers decoded after it.
    suffix = np.cumsum(rx_s[..., ::-1], axis=-1)[..., ::-1]
    tail = np.concatenate([suffix[..., 1:], np.zeros_like(suffix[..., :1])], axis=-1)
    rates_sorted = np.log2(1.0 + rx_s / (tail + noise_power))
    out = np.empty_like(rates_sorted)
    np.put_along_axis(out, order, rates_sorted, axis=-1)
    return out


def batched_weighted_rates(powers_vk, gains_vk, weights_vk, noise_power: float) -> np.ndarray:
    """Weighted sum rate of V candidate groups in one shot: (V, K) -> (V,).

    powers_vk / gains_vk / weights_vk are per-group rows; the reduction over
    K is done in input order (matching the scalar ``power.weighted_rate``).
    """
    w = np.asarray(weights_vk, dtype=np.float64)
    return np.sum(w * sic_rates(powers_vk, gains_vk, noise_power), axis=-1)


def weighted_rate(powers, gains, weights, noise_power: float) -> float:
    """Scalar convenience wrapper: one group's weighted sum rate."""
    return float(
        batched_weighted_rates(
            np.atleast_2d(np.asarray(powers, dtype=np.float64)),
            np.atleast_2d(np.asarray(gains, dtype=np.float64)),
            np.atleast_2d(np.asarray(weights, dtype=np.float64)),
            noise_power,
        )[0]
    )
