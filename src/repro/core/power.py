"""Power allocation for one scheduled NOMA group (paper §III-C).

The weighted sum-rate objective for a fixed decode order is

    max_p  prod_k ( mu_k(p) / phi_k(p) )^{w_k}
    s.t.   0 <= p_k <= p_k^max

with mu_k(p) = sum_{j>=k} p_j h_j^2 + sigma^2 and phi_k = sum_{j>k} p_j h_j^2
+ sigma^2, i.e. z_k := mu_k/phi_k = 1 + SINR_k.  This is a multiplicative
linear fractional program (MLFP); the paper solves it with the MAPEL polyblock
outer-approximation algorithm [Qian et al., 2009].

Key structural fact used throughout (and by the tests): for a *fixed decode
order* and target ratios z_k >= 1, the minimal power vector achieving them is
closed form, solving Eq. (13) back-to-front:

    p_K = (z_K - 1) sigma^2 / h_K^2
    p_k = (z_k - 1) (sum_{j>k} p_j h_j^2 + sigma^2) / h_k^2.

A z-target is feasible iff this minimal p lies in the power box. MAPEL then
reduces to a monotone optimization over the normal set of feasible z vectors,
implemented below with polyblock vertices kept in float64 on the host (this is
control-plane math: K <= 4, a few hundred iterations).

Decode order: following the uplink-NOMA convention (and the paper's WLOG
sorting) we fix the decode order by channel gain, strongest first.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import rates as rates_lib


@dataclasses.dataclass
class PowerSolution:
    powers: np.ndarray          # (K,) allocated powers, input (unsorted) order
    weighted_rate: float        # sum_k w_k log2(1 + SINR_k)
    iterations: int
    gap: float                  # polyblock optimality gap (objective domain)


def _objective(z: np.ndarray, weights: np.ndarray) -> float:
    """prod z_k^{w_k}, evaluated in log-domain for stability."""
    return float(np.exp(np.sum(weights * np.log(np.maximum(z, 1e-300)))))


def min_powers_for_targets(
    z: np.ndarray, gains_sorted: np.ndarray, noise_power: float
) -> np.ndarray:
    """Minimal powers (decode order) achieving ratio targets z (>=1)."""
    k = len(z)
    p = np.zeros(k, dtype=np.float64)
    # g*g, not g**2: scalar float64 ** goes through pow() and can differ from
    # the array fast path by 1 ulp — the plain multiply is deterministic, so
    # mapel_batched reproduces this back-substitution bit-for-bit.
    g2 = np.asarray(gains_sorted) * np.asarray(gains_sorted)
    interference = noise_power
    for i in range(k - 1, -1, -1):
        p[i] = (z[i] - 1.0) * interference / g2[i]
        interference += p[i] * g2[i]
    return p


def feasible(z: np.ndarray, gains_sorted, pmax, noise_power) -> bool:
    if np.any(z < 1.0):
        return False
    p = min_powers_for_targets(z, gains_sorted, noise_power)
    return bool(np.all(p <= pmax * (1.0 + 1e-12)))


def _project(z: np.ndarray, gains_sorted, pmax, noise_power, tol=1e-12):
    """MAPEL projection: largest lam in (0,1] with 1 + lam*(z-1) feasible.

    We project along the ray in (z - 1) (= SINR) space which keeps the
    projection inside the box [1, z] and preserves the polyblock invariants.
    """
    lo, hi = 0.0, 1.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if feasible(1.0 + mid * (z - 1.0), gains_sorted, pmax, noise_power):
            lo = mid
        else:
            hi = mid
        if hi - lo < tol:
            break
    return 1.0 + lo * (z - 1.0)


def _coordinate_polish(p0, gains, weights, pmax, noise_power,
                       *, rounds: int = 4, points: int = 33) -> np.ndarray:
    """Deterministic coordinate ascent on the box (polishes the MAPEL
    incumbent; the polyblock gives the global-optimality certificate, the
    polish closes the outer-approximation tail quickly for K <= 4)."""
    p = np.array(p0, dtype=np.float64)
    grid = np.linspace(0.0, pmax, points)
    for _ in range(rounds):
        improved = False
        for k in range(len(p)):
            best_v, best_pk = weighted_rate(p, gains, weights, noise_power), p[k]
            for cand in grid:
                p[k] = cand
                v = weighted_rate(p, gains, weights, noise_power)
                if v > best_v + 1e-12:
                    best_v, best_pk = v, cand
                    improved = True
            p[k] = best_pk
        if not improved:
            break
    return p


def mapel(
    gains: np.ndarray,
    weights: np.ndarray,
    pmax: float,
    noise_power: float,
    *,
    eps: float = 1e-3,
    max_iter: int = 300,
) -> PowerSolution:
    """MAPEL polyblock algorithm for the weighted sum-rate MLFP.

    gains, weights: (K,) in arbitrary (input) order. Returns powers in the
    same input order. eps is the relative optimality gap on the objective.
    The polyblock loop is capped at ``max_iter`` vertex expansions and the
    incumbent is finished with a coordinate-ascent polish (the raw outer
    approximation converges slowly near the boundary; see tests/test_power).
    """
    gains = np.asarray(gains, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    k = len(gains)
    # decode order: strongest first; stable so gain ties keep input order
    # (mapel_batched uses the same rule — the two must match exactly)
    order = np.argsort(-gains, kind="stable")
    g = gains[order]
    w = weights[order]

    if k == 1:
        p = np.array([pmax])
        z = 1.0 + p[0] * (g[0] * g[0]) / noise_power
        rate = float(w[0] * np.log2(z))
        out = np.zeros(1)
        out[order] = p
        return PowerSolution(out, rate, 0, 0.0)

    # Initial polyblock vertex: interference-free upper bound on each z_k.
    z_top = 1.0 + pmax * g**2 / noise_power
    vertices = [z_top]
    best_z = _project(z_top, g, pmax, noise_power)
    best_val = _objective(best_z, w)
    # Seed the incumbent with the all-max-power corner (often optimal in the
    # noise-limited regime of the paper's cell).
    z_corner = _z_of_powers(np.full(k, pmax), g, noise_power)
    if _objective(z_corner, w) > best_val:
        best_z, best_val = z_corner, _objective(z_corner, w)

    it = 0
    gap = np.inf
    while it < max_iter and vertices:
        it += 1
        vals = np.array([_objective(v, w) for v in vertices])
        i_best = int(np.argmax(vals))
        v = vertices.pop(i_best)
        ub = vals[i_best]
        gap = (ub - best_val) / max(best_val, 1e-12)
        if gap <= eps:
            break
        proj = _project(v, g, pmax, noise_power)
        val = _objective(proj, w)
        if val > best_val:
            best_val, best_z = val, proj
        # Split the vertex: v_j -> proj_j along each coordinate.
        for j in range(k):
            if proj[j] < v[j] - 1e-12:
                nv = v.copy()
                nv[j] = proj[j]
                vertices.append(nv)
        # Prune vertices that cannot beat the incumbent.
        vertices = [u for u in vertices if _objective(u, w) > best_val * (1 + eps / 4)]

    p_sorted = np.minimum(
        min_powers_for_targets(best_z, g, noise_power), pmax
    )
    # polish from two starts (polyblock incumbent + max-power corner): the
    # coordinate ascent is exact along axes but can sit in a basin when the
    # incumbent projection landed far from the optimum face.
    cands = [
        _coordinate_polish(p_sorted, g, w, pmax, noise_power),
        _coordinate_polish(np.full(k, pmax), g, w, pmax, noise_power),
    ]
    p_sorted = max(cands, key=lambda p: weighted_rate(p, g, w, noise_power))
    powers = np.zeros(k)
    powers[order] = p_sorted
    # Recompute the achieved weighted rate from the actual powers.
    rate = weighted_rate(powers, gains, weights, noise_power)
    return PowerSolution(powers, rate, it, float(max(gap, 0.0)))


def _z_of_powers(p, gains_sorted, noise_power):
    k = len(p)
    z = np.empty(k)
    for i in range(k):
        mu = np.sum(p[i:] * gains_sorted[i:] ** 2) + noise_power
        phi = np.sum(p[i + 1 :] * gains_sorted[i + 1 :] ** 2) + noise_power
        z[i] = mu / phi
    return z


# --------------------------------------------------------------------------
# Batched MAPEL: lockstep polyblock over G independent groups
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedPowerSolution:
    """mapel() over G groups at once; row g mirrors PowerSolution for group g."""

    powers: np.ndarray          # (G, K) allocated powers, input order per row
    weighted_rates: np.ndarray  # (G,)
    iterations: np.ndarray      # (G,) polyblock vertex expansions
    gaps: np.ndarray            # (G,) final optimality gaps


def _objective_rows(z_rows: np.ndarray, weights) -> np.ndarray:
    """prod_k z_k^{w_k} per row; weights broadcasts (K,) or (..., K)."""
    return np.exp(
        np.sum(weights * np.log(np.maximum(z_rows, 1e-300)), axis=-1)
    )


def _min_powers_batched(z_gk, gains_gk_sorted, noise_power) -> np.ndarray:
    """Row-wise min_powers_for_targets: same back-substitution, (G,) lanes."""
    k = z_gk.shape[1]
    p = np.zeros_like(z_gk)
    g2 = gains_gk_sorted * gains_gk_sorted     # see min_powers_for_targets
    interference = np.full(z_gk.shape[0], noise_power, dtype=np.float64)
    for i in range(k - 1, -1, -1):
        p[:, i] = (z_gk[:, i] - 1.0) * interference / g2[:, i]
        interference = interference + p[:, i] * g2[:, i]
    return p


def _feasible_batched(z_gk, gains_gk_sorted, pmax, noise_power) -> np.ndarray:
    ok = ~np.any(z_gk < 1.0, axis=1)
    p = _min_powers_batched(z_gk, gains_gk_sorted, noise_power)
    return ok & np.all(p <= pmax * (1.0 + 1e-12), axis=1)


def _project_batched(z_gk, gains_gk_sorted, pmax, noise_power, tol=1e-12):
    """Row-wise _project: one shared bisection, rows freeze at their own tol
    step so each row reproduces the scalar bisection's early break exactly."""
    g = z_gk.shape[0]
    lo, hi = np.zeros(g), np.ones(g)
    active = np.ones(g, dtype=bool)
    for _ in range(80):
        if not active.any():
            break
        mid = 0.5 * (lo + hi)
        feas = _feasible_batched(
            1.0 + mid[:, None] * (z_gk - 1.0), gains_gk_sorted, pmax, noise_power
        )
        lo = np.where(active & feas, mid, lo)
        hi = np.where(active & ~feas, mid, hi)
        active = active & ((hi - lo) >= tol)
    return 1.0 + lo[:, None] * (z_gk - 1.0)


def _z_of_powers_batched(p_gk, gains_gk_sorted, noise_power) -> np.ndarray:
    k = p_gk.shape[1]
    z = np.empty_like(p_gk)
    for i in range(k):
        mu = np.sum(p_gk[:, i:] * gains_gk_sorted[:, i:] ** 2, axis=1) + noise_power
        phi = (
            np.sum(p_gk[:, i + 1:] * gains_gk_sorted[:, i + 1:] ** 2, axis=1)
            + noise_power
        )
        z[:, i] = mu / phi
    return z


def _polish_batched(p0_gk, gains_gk_sorted, weights_gk_sorted, pmax, noise_power,
                    *, rounds: int = 4, points: int = 33) -> np.ndarray:
    """Row-wise _coordinate_polish: the grid sweep over each coordinate is one
    batched rate-engine call per candidate instead of G scalar evaluations;
    rows keep the scalar's strict-improvement/first-wins acceptance and stop
    sweeping once a full round makes no progress (per-row active mask)."""
    p = np.array(p0_gk, dtype=np.float64)
    g_cnt, k_cnt = p.shape
    grid = np.linspace(0.0, pmax, points)
    active = np.ones(g_cnt, dtype=bool)
    for _ in range(rounds):
        improved = np.zeros(g_cnt, dtype=bool)
        for k in range(k_cnt):
            best_v = rates_lib.batched_weighted_rates(
                p, gains_gk_sorted, weights_gk_sorted, noise_power
            )
            best_pk = p[:, k].copy()
            for cand in grid:
                ptmp = p.copy()
                ptmp[:, k] = cand
                v = rates_lib.batched_weighted_rates(
                    ptmp, gains_gk_sorted, weights_gk_sorted, noise_power
                )
                upd = active & (v > best_v + 1e-12)
                best_v = np.where(upd, v, best_v)
                best_pk = np.where(upd, cand, best_pk)
                improved |= upd
            p[:, k] = np.where(active, best_pk, p[:, k])
        active &= improved
        if not active.any():
            break
    return p


def mapel_batched(
    gains_gk: np.ndarray,
    weights_gk: np.ndarray,
    pmax: float,
    noise_power: float,
    *,
    eps: float = 1e-3,
    max_iter: int = 300,
) -> BatchedPowerSolution:
    """MAPEL over G groups in lockstep — group-for-group identical to
    ``[mapel(g_i, w_i, ...) for i]`` (tests assert bit equality).

    The schedulers' finalization path uses this to refine the power
    allocation of all T selected groups in one call: the polyblock vertex
    bookkeeping stays per group (it is data dependent), but the hot inner
    loops — the 80-step projection bisections, the feasibility
    back-substitutions, and the coordinate-ascent polish grid — run
    vectorized across every still-active group.

    gains_gk / weights_gk: (G, K) rows in arbitrary (input) order; returns
    powers in the same per-row input order.
    """
    gains = np.asarray(gains_gk, dtype=np.float64)
    weights = np.asarray(weights_gk, dtype=np.float64)
    g_cnt, k_cnt = gains.shape
    if g_cnt == 0 or k_cnt == 0:
        return BatchedPowerSolution(
            np.zeros((g_cnt, k_cnt)), np.zeros(g_cnt),
            np.zeros(g_cnt, dtype=int), np.zeros(g_cnt),
        )
    order = np.argsort(-gains, axis=1, kind="stable")   # strongest first
    g = np.take_along_axis(gains, order, axis=1)
    w = np.take_along_axis(weights, order, axis=1)

    if k_cnt == 1:
        p_sorted = np.full((g_cnt, 1), pmax)
        z = 1.0 + p_sorted[:, 0] * (g[:, 0] * g[:, 0]) / noise_power
        rate = w[:, 0] * np.log2(z)
        powers = np.zeros((g_cnt, 1))
        np.put_along_axis(powers, order, p_sorted, axis=1)
        return BatchedPowerSolution(
            powers, rate, np.zeros(g_cnt, dtype=int), np.zeros(g_cnt)
        )

    z_top = 1.0 + pmax * g**2 / noise_power
    verts = [[z_top[i]] for i in range(g_cnt)]
    best_z = _project_batched(z_top, g, pmax, noise_power)
    best_val = _objective_rows(best_z, w)
    z_corner = _z_of_powers_batched(np.full((g_cnt, k_cnt), pmax), g, noise_power)
    corner_val = _objective_rows(z_corner, w)
    take = corner_val > best_val
    best_z = np.where(take[:, None], z_corner, best_z)
    best_val = np.where(take, corner_val, best_val)

    it = np.zeros(g_cnt, dtype=int)
    gap = np.full(g_cnt, np.inf)
    done = np.zeros(g_cnt, dtype=bool)
    while True:
        active = [
            i for i in range(g_cnt) if not done[i] and it[i] < max_iter and verts[i]
        ]
        if not active:
            break
        popped = []
        for i in active:
            it[i] += 1
            vals = _objective_rows(np.asarray(verts[i]), w[i])
            j = int(np.argmax(vals))
            v = verts[i].pop(j)
            ub = float(vals[j])
            gap[i] = (ub - best_val[i]) / max(best_val[i], 1e-12)
            if gap[i] <= eps:
                done[i] = True
            else:
                popped.append((i, v))
        if not popped:
            continue
        idxs = np.asarray([i for i, _ in popped])
        zs = np.stack([v for _, v in popped])
        projs = _project_batched(zs, g[idxs], pmax, noise_power)
        vals_p = _objective_rows(projs, w[idxs])
        for (i, v), proj, val in zip(popped, projs, vals_p):
            if val > best_val[i]:
                best_val[i], best_z[i] = val, proj
            for j in range(k_cnt):
                if proj[j] < v[j] - 1e-12:
                    nv = v.copy()
                    nv[j] = proj[j]
                    verts[i].append(nv)
            if verts[i]:
                keep = _objective_rows(np.asarray(verts[i]), w[i]) > best_val[i] * (
                    1 + eps / 4
                )
                verts[i] = [u for u, kp in zip(verts[i], keep) if kp]

    p_sorted = np.minimum(_min_powers_batched(best_z, g, noise_power), pmax)
    cand_a = _polish_batched(p_sorted, g, w, pmax, noise_power)
    cand_b = _polish_batched(np.full((g_cnt, k_cnt), pmax), g, w, pmax, noise_power)
    val_a = rates_lib.batched_weighted_rates(cand_a, g, w, noise_power)
    val_b = rates_lib.batched_weighted_rates(cand_b, g, w, noise_power)
    use_b = val_b > val_a           # scalar max() keeps the first on ties
    p_fin = np.where(use_b[:, None], cand_b, cand_a)
    powers = np.zeros((g_cnt, k_cnt))
    np.put_along_axis(powers, order, p_fin, axis=1)
    rate = rates_lib.batched_weighted_rates(powers, gains, weights, noise_power)
    return BatchedPowerSolution(powers, rate, it, np.maximum(gap, 0.0))


# --------------------------------------------------------------------------
# PowerAllocator: the one object that owns power allocation
# --------------------------------------------------------------------------

POWER_MODES = ("max", "mapel", "ota-align")


def ota_align_powers(gains, weights, pmax: float) -> np.ndarray:
    """OTA alignment powers: truncated channel inversion at schedule time.

    Under the over-the-air uplink (core/ota.py) device k transmits
    ``sqrt(eta) * w_k / h_k`` per coordinate, so its *planned* power (the
    control-plane view: unit-norm update convention — realized per-round
    energies are data the scheduler never sees) is

        p_k = eta * w_k^2 / h_k^2,     eta = min_k pmax * h_k^2 / w_k^2

    — the binding (weakest-inversion) device transmits at exactly pmax and
    everyone else backs off so the received amplitudes stay aligned with
    the FedAvg weights.  Zero-gain or zero-weight devices are excluded
    from the eta min and allocated zero (they cannot invert / contribute
    nothing).  Input (unsorted) order in, same order out.
    """
    g = np.asarray(gains, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    live = (g > 0.0) & (w > 0.0)
    if not live.any():
        return np.zeros(g.shape, dtype=np.float64)
    with np.errstate(divide="ignore"):
        caps = np.where(live, pmax * g * g / np.maximum(w * w, 1e-300), np.inf)
    eta = float(np.min(caps))
    with np.errstate(divide="ignore"):
        p = np.where(live, eta * w * w / np.maximum(g * g, 1e-300), 0.0)
    return np.minimum(p, pmax)   # the min-cap guarantees this; belt and braces


TRACED_POWER_MODES = ("max", "ota-align")
# Modes with a closed-form jnp mirror (:func:`traced_round_powers`), i.e.
# the modes the device-resident online horizon supports.  "mapel" is the
# host-iterative polyblock search and stays per-round only — config
# validation pins the rejection (errors.ERR_SCAN_ONLINE_MAPEL).


def traced_round_powers(mode: str, gains_k, weights_k, pmax: float):
    """jnp mirror of :meth:`PowerAllocator.solve` for the traced round body.

    Operates on one masked (K,) group inside the scanned online horizon
    (``fl_engine._online_horizon_core``): padding lanes arrive with zero
    gain/weight and are allocated zero power, which zeroes their SIC rate
    and bit budget — exactly how the host allocator's absence of those
    lanes plays out.  ``mode`` is static (trace-time dispatch); only the
    closed-form modes in :data:`TRACED_POWER_MODES` are supported.
    """
    import jax.numpy as jnp

    g = jnp.asarray(gains_k)
    w = jnp.asarray(weights_k)
    if mode == "max":
        return jnp.where(g > 0.0, jnp.float32(pmax), 0.0)
    if mode != "ota-align":
        raise ValueError(
            f"power mode {mode!r} has no traced allocator; "
            f"supported: {TRACED_POWER_MODES}"
        )
    live = (g > 0.0) & (w > 0.0)
    caps = jnp.where(
        live, pmax * g * g / jnp.maximum(w * w, 1e-30), jnp.inf
    )
    eta = jnp.min(caps)     # inf when nothing is live: zeroed below
    p = jnp.where(live, eta, 0.0) * w * w / jnp.maximum(g * g, 1e-30)
    return jnp.minimum(p, pmax)


@dataclasses.dataclass(frozen=True)
class PowerAllocator:
    """Power allocation for scheduled NOMA groups, single or batched.

    ``solve`` allocates one group ((K,) gains/weights -> (K,) powers);
    ``solve_batched`` allocates V groups in one call ((V, K) -> (V, K)).
    For ``mode="mapel"`` the batched form is the lockstep polyblock
    (:func:`mapel_batched`), which reproduces the sequential solver
    group-for-group; ``mode="max"`` is the no-power-control baseline;
    ``mode="ota-align"`` is the over-the-air channel-inversion alignment
    (:func:`ota_align_powers` — FLConfig restricts it to uplink="ota").

    Instances are also callable ((gains, weights) -> powers) and expose
    ``batched`` as an alias of ``solve_batched``, so every legacy
    ``PowerFn`` call site (``scheduling.score_subsets``, the schedulers'
    finalization) works unchanged.
    """

    mode: str
    pmax: float
    noise_power: float
    eps: float = 1e-3           # MAPEL relative optimality gap

    def __post_init__(self):
        if self.mode not in POWER_MODES:
            raise ValueError(
                f"unknown power mode {self.mode!r}; known: {POWER_MODES}"
            )

    def solve(self, gains_k, weights_k) -> np.ndarray:
        """(K,) powers for one group, input (unsorted) order."""
        if self.mode == "max":
            return max_power(gains_k, self.pmax)
        if self.mode == "ota-align":
            return ota_align_powers(gains_k, weights_k, self.pmax)
        return mapel(
            gains_k, weights_k, self.pmax, self.noise_power, eps=self.eps
        ).powers

    def solve_batched(self, gains_vk, weights_vk) -> np.ndarray:
        """(V, K) powers for V groups in one call."""
        if self.mode == "max":
            return np.full(np.shape(gains_vk), self.pmax, dtype=np.float64)
        if self.mode == "ota-align":
            gains_vk = np.asarray(gains_vk, dtype=np.float64)
            weights_vk = np.asarray(weights_vk, dtype=np.float64)
            return np.stack([
                ota_align_powers(g, w, self.pmax)
                for g, w in zip(gains_vk, weights_vk)
            ]) if len(gains_vk) else np.zeros(np.shape(gains_vk))
        return mapel_batched(
            gains_vk, weights_vk, self.pmax, self.noise_power, eps=self.eps
        ).powers

    def __call__(self, gains_k, weights_k) -> np.ndarray:
        return self.solve(gains_k, weights_k)

    @property
    def batched(self):
        return self.solve_batched


def make_power_allocator(
    mode: str, pmax: float, noise_power: float
) -> PowerAllocator:
    """Factory behind ``FLConfig.power_mode`` (raises on unknown modes)."""
    return PowerAllocator(mode, pmax, noise_power)


def max_power(gains: np.ndarray, pmax: float) -> np.ndarray:
    """No-power-control baseline: everyone transmits at p^max (paper §IV)."""
    return np.full(len(np.atleast_1d(gains)), pmax, dtype=np.float64)


def weighted_rate(powers, gains, weights, noise_power) -> float:
    """sum_k w_k log2(1 + SINR_k) under SIC, input order.

    Thin wrapper over the shared batched engine (repro.core.rates) so MAPEL,
    the schedulers, and the kernels all agree on one SIC rate definition.
    """
    return rates_lib.weighted_rate(powers, gains, weights, noise_power)


def grid_oracle(
    gains, weights, pmax, noise_power, *, points: int = 40
) -> PowerSolution:
    """Brute-force grid search oracle (tests only; exponential in K)."""
    gains = np.asarray(gains, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    k = len(gains)
    axes = [np.linspace(0.0, pmax, points) for _ in range(k)]
    best, best_p = -np.inf, None
    grid = np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1).reshape(-1, k)
    for p in grid:
        val = weighted_rate(p, gains, weights, noise_power)
        if val > best:
            best, best_p = val, p
    return PowerSolution(np.asarray(best_p), float(best), len(grid), 0.0)
