"""Device-resident mirror of the batched SIC rate engine (jnp/XLA).

``repro.core.rates`` is the numpy control-plane engine the schedulers call
from inside Python greedy loops.  This module is the same math on the device
path, in two layers:

  * :func:`sic_rates` / :func:`batched_weighted_rates` — jnp mirrors of the
    numpy engine with identical decode-order semantics (descending receive
    power, ties broken by lower input index via a *stable* argsort) and the
    identical shifted-suffix-sum interference formulation, so numpy and jnp
    agree on which candidate subset wins an argmax.  Both broadcast over
    arbitrary leading batch axes; the MWIS greedy feeds a whole
    ``(T_rem, V, K)`` tensor of (round, candidate-subset) vertices at once.

  * :func:`greedy_step` — one jitted call per greedy step of the lazy GWMIN
    scheduler (``repro.core.scheduling.lazy_greedy_schedule(backend="jax")``).
    The C(pool, K) subset enumeration is built **once** on the host as
    position tuples into a per-round candidate pool; each step re-masks
    availability on device, re-ranks the pool by the precomputed solo-rate
    proxy, scores every (round, subset) vertex, and returns the argmax vertex
    plus the updated availability/done masks.  Nothing of size O(T*V) ever
    leaves the device.

Precision: the numpy engine is float64, so callers run this module under
``jax.experimental.enable_x64()`` (the scheduling driver does) to keep the
argmax tie-breaking bit-compatible with the host path.  Without x64 the same
code runs in float32 — fine for kernels, not for schedule equivalence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def sic_rates(powers, gains, noise_power: float) -> jax.Array:
    """Per-device SIC spectral efficiencies, input order (jnp mirror).

    powers, gains: (..., K) arrays (any matching leading batch axes).
    Decode order = descending receive power, ties by lower input index
    (stable argsort) — identical to ``repro.core.rates.sic_rates``.
    """
    p = jnp.asarray(powers)
    g = jnp.asarray(gains)
    rx = p * g * g
    order = jnp.argsort(-rx, axis=-1, stable=True)
    rx_s = jnp.take_along_axis(rx, order, axis=-1)
    # Shifted suffix sum (not suffix - rx): bit-compatible with the numpy
    # engine, whose tail_i is exactly the cumsum partial at position i+1.
    suffix = jnp.cumsum(rx_s[..., ::-1], axis=-1)[..., ::-1]
    tail = jnp.concatenate(
        [suffix[..., 1:], jnp.zeros_like(suffix[..., :1])], axis=-1
    )
    rates_sorted = jnp.log2(1.0 + rx_s / (tail + noise_power))
    return jnp.put_along_axis(
        jnp.zeros_like(rates_sorted), order, rates_sorted, axis=-1,
        inplace=False,
    )


def batched_weighted_rates(powers, gains, weights, noise_power: float) -> jax.Array:
    """Weighted SIC sum rates over any leading batch axes: (..., K) -> (...).

    Sort-based exact mirror of the numpy engine; the kernels' jnp oracle
    (``repro.kernels.ref``) calls it on (V, K) rows.
    """
    w = jnp.asarray(weights)
    return jnp.sum(w * sic_rates(powers, gains, noise_power), axis=-1)


def weighted_rates_cmp(powers, gains, weights, noise_power: float) -> jax.Array:
    """Sort-free weighted SIC sum rates: (..., K) -> (...), K unrolled.

    The O(K^2) comparison-matrix form of the same decode order (descending
    receive power, ties to the lower index) used by the Pallas kernel
    (``repro.kernels.sic_rates``): interference for user i is the sum of
    receive powers decoded after it,

        tail_i = sum_j rx_j * [rx_j < rx_i or (rx_j == rx_i and j > i)].

    On CPU/TPU XLA this is pure elementwise work — 30x faster than the
    argsort/scatter mirror on the greedy's (T, V, K) vertex tensors, at the
    cost of a different interference summation *order* (input order instead
    of decode order), i.e. ULP-level differences from ``sic_rates``.  The
    greedy argmax is insensitive to those (distinct subsets are separated by
    far more than an ulp on any non-degenerate instance; the backend
    equivalence tests pin this).
    """
    p = jnp.asarray(powers)
    g = jnp.asarray(gains)
    w = jnp.asarray(weights)
    rx = p * g * g
    k = rx.shape[-1]
    acc = jnp.zeros(rx.shape[:-1], rx.dtype)
    for i in range(k):
        rxi = rx[..., i]
        tail = jnp.zeros_like(rxi)
        for j in range(k):
            if j == i:
                continue
            rxj = rx[..., j]
            decoded_after = (rxj < rxi) | ((rxj == rxi) & (j > i))
            tail = tail + jnp.where(decoded_after, rxj, 0.0)
        acc = acc + w[..., i] * jnp.log2(1.0 + rxi / (tail + noise_power))
    return acc


@functools.partial(
    jax.jit, static_argnames=("pool", "pmax", "noise_power")
)
def greedy_step(
    gains_tm: jax.Array,     # (T, M) channel gains, whole horizon
    weights_m: jax.Array,    # (M,) device weights
    solo_tm: jax.Array,      # (T, M) solo-rate pool-ranking proxy (host f64)
    subs_pos_vk: jax.Array,  # (V, K) int32 subsets as pool *positions*, lex order
    avail_m: jax.Array,      # (M,) bool: device not yet scheduled
    done_t: jax.Array,       # (T,) bool: round already assigned
    *,
    pool: int,
    pmax: float,
    noise_power: float,
):
    """One GWMIN greedy step: argmax-weight (subset, round) vertex on device.

    Per remaining round, the ``pool`` strongest available devices (by the
    solo-rate proxy, ties to the lower device id) form the candidate pool,
    sorted ascending by device id so ``subs_pos_vk``'s lexicographic position
    tuples map to the same subsets the numpy path enumerates.  Unavailable
    pool slots are pushed past ``n_valid`` with an id-M sentinel; any subset
    touching one (its last position, subsets being sorted) is masked to -inf,
    as are completed rounds.  The flat argmax is t-major / subset-lex-minor —
    the numpy path's exact tie-breaking (earliest round, first subset).

    Returns (best_val, t_star, subset_device_ids, avail_new, done_new); a
    best_val of -inf means no feasible vertex (caller stops or falls back to
    the host tail path for leftover groups smaller than K).
    """
    t_cnt, m = gains_tm.shape
    v_cnt = subs_pos_vk.shape[0]
    solo_masked = jnp.where(avail_m[None, :], solo_tm, -jnp.inf)
    order = jnp.argsort(-solo_masked, axis=1, stable=True)[:, :pool]  # (T, pool)
    n_valid = jnp.minimum(jnp.sum(avail_m), pool)
    valid_slot = jnp.arange(pool)[None, :] < n_valid
    kept = jnp.where(valid_slot, order, m)          # sentinel id M past n_valid
    kept_sorted = jnp.sort(kept, axis=1)            # ascending ids, sentinels last
    safe_ids = jnp.minimum(kept_sorted, m - 1)
    g_pool = jnp.take_along_axis(gains_tm, safe_ids, axis=1)     # (T, pool)
    w_pool = weights_m[safe_ids]                                 # (T, pool)
    g_tvk = g_pool[:, subs_pos_vk]                               # (T, V, K)
    w_tvk = w_pool[:, subs_pos_vk]
    p_tvk = jnp.full(g_tvk.shape, pmax, g_tvk.dtype)
    scores = weighted_rates_cmp(p_tvk, g_tvk, w_tvk, noise_power)  # (T, V)
    valid_v = subs_pos_vk[:, -1] < n_valid          # positions ascending per row
    ok = valid_v[None, :] & jnp.logical_not(done_t)[:, None]
    flat = jnp.where(ok, scores, -jnp.inf).reshape(-1)
    idx = jnp.argmax(flat)                          # first max: t-major order
    val = flat[idx]
    t_star = idx // v_cnt
    sub_ids = kept_sorted[t_star, subs_pos_vk[idx % v_cnt]]      # (K,)
    feasible = val > -jnp.inf
    # Out-of-range sentinel scatters are dropped by jax; the where() guards
    # the infeasible case anyway.
    avail_new = jnp.where(feasible, avail_m.at[sub_ids].set(False), avail_m)
    done_new = jnp.where(feasible, done_t.at[t_star].set(True), done_t)
    return val, t_star, sub_ids, avail_new, done_new
