"""Batched SIC rate engine (repro.core.rates) and its accelerator mirrors."""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.core import power, rates, scheduling

NOISE = 1.6e-14
PMAX = 0.01


def _batch(v, k, seed, pmax=PMAX):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (v, k))) + 1e-8
    powers = rng.uniform(0.0, pmax, (v, k))
    weights = rng.dirichlet(np.ones(k), size=v)
    return powers, gains, weights


def _paper_reference_row(p, g, w, noise):
    """Straight-from-the-paper scalar SIC chain (Eq. 2-4), no vectorization:
    decode descending receive power, interference = undecoded tail."""
    rx = p * g**2
    order = sorted(range(len(rx)), key=lambda i: (-rx[i], i))
    total = 0.0
    for pos, i in enumerate(order):
        tail = sum(rx[j] for j in order[pos + 1 :])
        total += w[i] * np.log2(1.0 + rx[i] / (tail + noise))
    return total


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_batched_matches_paper_reference(k, v, seed):
    p, g, w = _batch(v, k, seed)
    got = rates.batched_weighted_rates(p, g, w, NOISE)
    want = [_paper_reference_row(p[i], g[i], w[i], NOISE) for i in range(v)]
    np.testing.assert_allclose(got, want, rtol=1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 32), st.integers(0, 2**31 - 1))
def test_batched_matches_scalar_weighted_rate(k, v, seed):
    """Elementwise agreement with the public scalar API (power.weighted_rate)."""
    p, g, w = _batch(v, k, seed)
    got = rates.batched_weighted_rates(p, g, w, NOISE)
    for i in range(v):
        assert got[i] == pytest.approx(
            power.weighted_rate(p[i], g[i], w[i], NOISE), rel=1e-12
        )


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 4), st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_permutation_invariance(k, v, seed):
    """The weighted sum rate of a group does not depend on input device order."""
    p, g, w = _batch(v, k, seed)
    base = rates.batched_weighted_rates(p, g, w, NOISE)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(k)
    shuffled = rates.batched_weighted_rates(
        p[:, perm], g[:, perm], w[:, perm], NOISE
    )
    np.testing.assert_allclose(shuffled, base, rtol=1e-12)


def test_sic_rates_matches_seed_formula():
    """sic_rates on a single row reproduces the seed's per-group _rates."""
    p, g, w = _batch(8, 3, 7)
    for i in range(8):
        rx = p[i] * g[i] ** 2
        order = np.argsort(-rx)
        rx_s = rx[order]
        tail = np.concatenate([np.cumsum(rx_s[::-1])[::-1][1:], [0.0]])
        want = np.zeros(3)
        want[order] = np.log2(1.0 + rx_s / (tail + NOISE))
        np.testing.assert_allclose(rates.sic_rates(p[i], g[i], NOISE), want,
                                   rtol=1e-12)


def test_jax_paths_match_numpy_engine():
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels import ops

    p, g, w = _batch(600, 3, 3)  # > one BLOCK_V tile for the pallas grid
    want = rates.batched_weighted_rates(p, g, w, NOISE)
    got_ref = np.asarray(
        ops.sic_weighted_rates(jnp.asarray(p), jnp.asarray(g), jnp.asarray(w), NOISE)
    )
    got_pallas = np.asarray(
        ops.sic_weighted_rates(
            jnp.asarray(p), jnp.asarray(g), jnp.asarray(w), NOISE, use_pallas=True
        )
    )
    # float32 device math vs float64 host engine
    np.testing.assert_allclose(got_ref, want, rtol=2e-4)
    np.testing.assert_allclose(got_pallas, want, rtol=2e-4)
    np.testing.assert_allclose(got_pallas, got_ref, rtol=2e-5)


def test_pallas_kernel_empty_candidate_batch():
    """Regression: a V=0 candidate batch used to hit a 0-block pallas grid
    (slice_sizes > operand shape); an empty batch scores to an empty (0,)
    result, matching the numpy engine."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.sic_rates import sic_weighted_rates_pallas

    out = sic_weighted_rates_pallas(
        jnp.zeros((0, 3)), jnp.zeros((0, 3)), jnp.zeros((0, 3)), NOISE
    )
    assert out.shape == (0,)
    want = rates.batched_weighted_rates(
        np.zeros((0, 3)), np.zeros((0, 3)), np.zeros((0, 3)), NOISE
    )
    assert want.shape == (0,)


# --------------------------------------------------------------------------
# Scheduler equivalence: batched engine vs the seed's per-subset Python loop
# --------------------------------------------------------------------------

def _seed_lazy_greedy(gains_tm, weights_m, k, *, pmax=PMAX, noise_power=NOISE,
                      candidate_pool=16):
    """The seed implementation, verbatim: one group_weighted_rate call per
    itertools.combinations subset per round (kept here as the ground truth
    the batched scheduler must reproduce)."""
    search_fn = scheduling.make_power_fn("max", pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    avail = set(range(num_devices))
    remaining = set(range(num_rounds))
    rounds = [()] * num_rounds
    while remaining and len(avail) > 0:
        best = (-np.inf, None, None)
        for t in sorted(remaining):
            av = np.asarray(sorted(avail))
            if len(av) > candidate_pool:
                g = gains_tm[t, av]
                solo = weights_m[av] * np.log2(1.0 + (pmax * g**2) / noise_power)
                keep = av[np.argsort(-solo)[:candidate_pool]]
            else:
                keep = av
            best_val, best_sub = -np.inf, None
            for subset in itertools.combinations(
                sorted(keep.tolist()), min(k, len(keep))
            ):
                val, _, _ = scheduling.group_weighted_rate(
                    subset, t, gains_tm, weights_m, search_fn, noise_power
                )
                if val > best_val:
                    best_val, best_sub = val, subset
            if best_val > best[0]:
                best = (best_val, best_sub, t)
        _, subset, t = best
        if subset is None:
            break
        rounds[t] = subset
        avail -= set(subset)
        remaining.discard(t)
    return list(map(tuple, rounds))


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 10), st.integers(1, 3), st.integers(1, 3),
       st.integers(0, 9999))
def test_batched_greedy_equals_seed_loop(m, k, t, seed):
    if m < k * t:
        return
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    want = _seed_lazy_greedy(gains, w, k)
    got = scheduling.lazy_greedy_schedule(gains, w, k, noise_power=NOISE)
    assert got.rounds == want


def test_batched_greedy_equals_seed_loop_with_candidate_pool():
    """Exercise the proxy-pool path (M > candidate_pool) too."""
    rng = np.random.default_rng(42)
    gains = np.abs(rng.normal(1e-6, 5e-7, (4, 24))) + 1e-8
    w = rng.dirichlet(np.ones(24))
    want = _seed_lazy_greedy(gains, w, 3, candidate_pool=8)
    got = scheduling.lazy_greedy_schedule(
        gains, w, 3, noise_power=NOISE, candidate_pool=8
    )
    assert got.rounds == want
    assert got.validate(24, 3)


def test_candidate_pool_proxy_respects_pmax():
    """Seed bug: the pool ranking hardcoded pmax=0.01. With a large power
    budget the weighted solo-rate ranking flips (log concavity), so the pool
    must be ranked at the caller's pmax to keep the right device."""
    noise = 1.0
    gains = np.array([[10.0, 1.0]])       # device 0: strong; device 1: weak
    weights = np.array([0.2, 1.0])        # ...but device 1 carries the weight
    # pmax=100: w1*log2(1 + 100*1) = 6.66 > w0*log2(1 + 100*100) = 2.66
    sched = scheduling.lazy_greedy_schedule(
        gains, weights, 1, pmax=100.0, noise_power=noise, candidate_pool=1
    )
    assert sched.rounds == [(1,)]
    # pmax=0.01 keeps the seed's ranking (device 0 wins)
    sched_small = scheduling.lazy_greedy_schedule(
        gains, weights, 1, pmax=0.01, noise_power=noise, candidate_pool=1
    )
    assert sched_small.rounds == [(0,)]
