"""Batched serving driver: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.launch import steps as steps_lib
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    max_len = args.prompt_len + args.gen + 1
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    extras = {}
    if cfg.family == "vlm":
        extras["img_feats"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        from repro.models import encdec

        enc_feats = jax.random.normal(
            jax.random.fold_in(key, 3),
            (args.batch, max(args.prompt_len, 8), cfg.d_model), jnp.bfloat16)
        extras["enc_out"] = encdec.encode(params, enc_feats, cfg)

    # prefill
    caches = model.init_cache(args.batch, max_len)
    kw = dict(extras)
    if cfg.family == "encdec":
        kw = {"enc_out": extras["enc_out"]}
    t0 = time.time()
    out = model.module.forward(params, prompts, cfg, caches=caches,
                               remat=False, **kw)
    logits, caches = out[0], out[1]
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    serve_step = jax.jit(steps_lib.make_serve_step(model))
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        batch = {"tokens": tok, **extras}
        tok, caches = serve_step(params, caches, batch)
        generated.append(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} prefill {args.prompt_len} tok in {t_prefill:.2f}s; "
          f"decoded {args.gen} tok in {t_decode:.2f}s "
          f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample generation (ids):", np.asarray(gen[0])[:16].tolist())
    assert gen.shape == (args.batch, args.gen)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
    return gen


if __name__ == "__main__":
    main()
