"""FLC007 corpus: optional deps must go through the ImportError shim.

The offline CI container ships neither ``hypothesis`` nor ``zstandard``;
a bare import crashes collection instead of degrading gracefully.  Never
executed — parsed only.
"""
import hypothesis  # expect: FLC007
from zstandard import ZstdCompressor  # expect: FLC007

try:
    import zstandard
except ImportError:  # the established shim: degrade to None
    zstandard = None

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
