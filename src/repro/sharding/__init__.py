from repro.sharding.rules import (
    AxisRules,
    DEFAULT_RULES,
    activation_specs,
    cache_pspec,
    param_pspecs,
    translate,
)
from repro.sharding.vertex import (
    VERTEX_AXIS,
    max_vertex_shards,
    pad_rows_to_multiple,
    vertex_mesh,
)
