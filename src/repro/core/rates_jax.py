"""Device-resident mirror of the batched SIC rate engine (jnp/XLA).

``repro.core.rates`` is the numpy control-plane engine the schedulers call
from inside Python greedy loops.  This module is the same math on the device
path, in three layers:

  * :func:`sic_rates` / :func:`batched_weighted_rates` — jnp mirrors of the
    numpy engine with identical decode-order semantics (descending receive
    power, ties broken by lower input index via a *stable* argsort) and the
    identical shifted-suffix-sum interference formulation, so numpy and jnp
    agree on which candidate subset wins an argmax.  Both broadcast over
    arbitrary leading batch axes; the MWIS greedy feeds a whole
    ``(T_rem, V, K)`` tensor of (round, candidate-subset) vertices at once.

  * :func:`greedy_step` — one jitted call per greedy step of the lazy GWMIN
    scheduler (``scheduling.lazy_greedy_schedule(backend="jax-stepwise")``).
    The C(pool, K) subset enumeration is built **once** on the host as
    position tuples into a per-round candidate pool; each step re-masks
    availability on device, re-ranks the pool by the precomputed solo-rate
    proxy, scores every (round, subset) vertex, and returns the argmax vertex
    plus the updated availability/done masks.  Nothing of size O(T*V) ever
    leaves the device, but each step still syncs scalars to the host.

  * :func:`greedy_rounds_fused` — the whole greedy selection loop as a
    single jitted ``lax.while_loop`` (``backend="jax"``, the default device
    path).  The carry is ``(step, feasible, avail_m, done_t, assign_tk)``:

        step      int32   greedy steps taken so far
        feasible  bool    last step found a finite-score vertex
        avail_m   (M,)    bool, device not yet scheduled
        done_t    (T,)    bool, round already assigned
        assign_tk (T, K)  int32 device ids, -1 where unassigned

    Each iteration re-ranks the candidate pools, scores the full (T, V, K)
    vertex tensor, takes the argmax vertex and writes it into ``assign_tk``
    — all on device.  The loop exits after min(T, M // K) steps or on the
    first infeasible step, and the caller syncs the final carry to the host
    exactly once per schedule (the T*K > M leftover tail falls back to the
    host path, as before).

    Two switches, both trace-time static:

      * ``scorer="xla"`` (default) scores vertices with
        :func:`weighted_rates_cmp`; ``scorer="pallas"`` lowers the same
        O(K^2) comparison-matrix math through the Pallas SIC kernel
        (``repro.kernels.sic_rates``, ``interpret=True`` on CPU, Mosaic on
        TPU).  The kernel accumulates in float32, so pallas-scored argmaxes
        can tie-flip vs the f64 XLA scorer on degenerate instances; the
        XLA scorer is the bit-identical-to-numpy path.
      * ``shards=N`` shards the V (candidate-subset) axis over the first N
        local devices via ``shard_map`` (``repro.sharding.vertex``): every
        shard scores its slice of the enumeration and the global argmax is
        an in-mesh reduction — ``lax.pmax`` on the score, then ``lax.pmin``
        on the t-major global flat index among the maxima, then a ``psum``
        one-hot gather of the winning subset's device ids, preserving the
        host path's earliest-round / lexicographically-first tie-break
        exactly.  ``shards=None`` skips ``shard_map`` entirely.

Precision: the numpy engine is float64, so callers run this module under
``jax.experimental.enable_x64()`` (the scheduling driver does) to keep the
argmax tie-breaking bit-compatible with the host path.  Without x64 the same
code runs in float32 — fine for kernels, not for schedule equivalence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

SCORERS = ("xla", "pallas")


def sic_rates(powers, gains, noise_power: float) -> jax.Array:
    """Per-device SIC spectral efficiencies, input order (jnp mirror).

    powers, gains: (..., K) arrays (any matching leading batch axes).
    Decode order = descending receive power, ties by lower input index
    (stable argsort) — identical to ``repro.core.rates.sic_rates``.
    """
    p = jnp.asarray(powers)
    g = jnp.asarray(gains)
    rx = p * g * g
    order = jnp.argsort(-rx, axis=-1, stable=True)
    rx_s = jnp.take_along_axis(rx, order, axis=-1)
    # Shifted suffix sum (not suffix - rx): bit-compatible with the numpy
    # engine, whose tail_i is exactly the cumsum partial at position i+1.
    suffix = jnp.cumsum(rx_s[..., ::-1], axis=-1)[..., ::-1]
    tail = jnp.concatenate(
        [suffix[..., 1:], jnp.zeros_like(suffix[..., :1])], axis=-1
    )
    rates_sorted = jnp.log2(1.0 + rx_s / (tail + noise_power))
    return jnp.put_along_axis(
        jnp.zeros_like(rates_sorted), order, rates_sorted, axis=-1,
        inplace=False,
    )


def batched_weighted_rates(powers, gains, weights, noise_power: float) -> jax.Array:
    """Weighted SIC sum rates over any leading batch axes: (..., K) -> (...).

    Sort-based exact mirror of the numpy engine; the kernels' jnp oracle
    (``repro.kernels.ref``) calls it on (V, K) rows.
    """
    w = jnp.asarray(weights)
    return jnp.sum(w * sic_rates(powers, gains, noise_power), axis=-1)


def weighted_rates_cmp(powers, gains, weights, noise_power: float) -> jax.Array:
    """Sort-free weighted SIC sum rates: (..., K) -> (...), K unrolled.

    The O(K^2) comparison-matrix form of the same decode order (descending
    receive power, ties to the lower index) used by the Pallas kernel
    (``repro.kernels.sic_rates``): interference for user i is the sum of
    receive powers decoded after it,

        tail_i = sum_j rx_j * [rx_j < rx_i or (rx_j == rx_i and j > i)].

    On CPU/TPU XLA this is pure elementwise work — 30x faster than the
    argsort/scatter mirror on the greedy's (T, V, K) vertex tensors, at the
    cost of a different interference summation *order* (input order instead
    of decode order), i.e. ULP-level differences from ``sic_rates``.  The
    greedy argmax is insensitive to those (distinct subsets are separated by
    far more than an ulp on any non-degenerate instance; the backend
    equivalence tests pin this).
    """
    p = jnp.asarray(powers)
    g = jnp.asarray(gains)
    w = jnp.asarray(weights)
    rx = p * g * g
    k = rx.shape[-1]
    acc = jnp.zeros(rx.shape[:-1], rx.dtype)
    for i in range(k):
        rxi = rx[..., i]
        tail = jnp.zeros_like(rxi)
        for j in range(k):
            if j == i:
                continue
            rxj = rx[..., j]
            decoded_after = (rxj < rxi) | ((rxj == rxi) & (j > i))
            tail = tail + jnp.where(decoded_after, rxj, 0.0)
        acc = acc + w[..., i] * jnp.log2(1.0 + rxi / (tail + noise_power))
    return acc


# --------------------------------------------------------------------------
# GWMIN greedy on device: shared vertex selection + step-wise / fused drivers
# --------------------------------------------------------------------------

def _score_vertices(g_tvk, w_tvk, pmax: float, noise_power: float, scorer: str):
    """(T, V, K) gains/weights -> (T, V) max-power weighted sum rates.

    ``scorer`` is trace-time static: "xla" runs :func:`weighted_rates_cmp`
    (f64 under x64, the bit-identical-to-numpy path); "pallas" flattens the
    vertex axes to one (T*V, K) candidate batch and runs the Pallas SIC
    comparison-matrix kernel (f32 accumulate — same decode order, ULP-level
    score differences).
    """
    if scorer == "xla":
        p_tvk = jnp.full(g_tvk.shape, pmax, g_tvk.dtype)
        return weighted_rates_cmp(p_tvk, g_tvk, w_tvk, noise_power)
    if scorer == "pallas":
        from repro.kernels import sic_rates as sic_kernel

        t_cnt, v_cnt, k = g_tvk.shape
        g_vk = g_tvk.reshape(t_cnt * v_cnt, k)
        w_vk = w_tvk.reshape(t_cnt * v_cnt, k)
        p_vk = jnp.full(g_vk.shape, pmax, g_vk.dtype)
        out = sic_kernel.sic_weighted_rates_pallas(p_vk, g_vk, w_vk, noise_power)
        return out.reshape(t_cnt, v_cnt).astype(g_tvk.dtype)
    raise ValueError(f"unknown scorer {scorer!r}; known: {SCORERS}")


def _select_vertex(
    gains_tm, weights_m, solo_tm, subs_pos_vk, avail_m, done_t,
    *, pool: int, pmax: float, noise_power: float, scorer: str = "xla",
    axis_name: str | None = None, n_shards: int = 1,
):
    """Argmax-weight (subset, round) vertex under the current masks.

    Per remaining round, the ``pool`` strongest available devices (by the
    solo-rate proxy, ties to the lower device id) form the candidate pool,
    sorted ascending by device id so ``subs_pos_vk``'s lexicographic position
    tuples map to the same subsets the numpy path enumerates.  Unavailable
    pool slots are pushed past ``n_valid`` with an id-M sentinel; any subset
    touching one (its last position, subsets being sorted) is masked to -inf,
    as are completed rounds.  The flat argmax is t-major / subset-lex-minor —
    the numpy path's exact tie-breaking (earliest round, first subset).

    With ``axis_name`` set, ``subs_pos_vk`` is this shard's slice of the
    enumeration (``n_shards`` slices of equal length, concatenated in lex
    order) and the argmax is combined across the mesh: pmax on the score,
    pmin on the t-major *global* flat index among score maxima, psum-gather
    of the (unique) owner shard's subset ids — bit-identical tie-breaking to
    the single-shard path.

    Returns ``(val, t_star, sub_ids)``; ``val == -inf`` means no feasible
    vertex remains.
    """
    t_cnt, m = gains_tm.shape
    v_cnt = subs_pos_vk.shape[0]
    solo_masked = jnp.where(avail_m[None, :], solo_tm, -jnp.inf)
    order = jnp.argsort(-solo_masked, axis=1, stable=True)[:, :pool]  # (T, pool)
    n_valid = jnp.minimum(jnp.sum(avail_m), pool)
    valid_slot = jnp.arange(pool)[None, :] < n_valid
    kept = jnp.where(valid_slot, order, m)          # sentinel id M past n_valid
    kept_sorted = jnp.sort(kept, axis=1)            # ascending ids, sentinels last
    safe_ids = jnp.minimum(kept_sorted, m - 1)
    g_pool = jnp.take_along_axis(gains_tm, safe_ids, axis=1)     # (T, pool)
    w_pool = weights_m[safe_ids]                                 # (T, pool)
    g_tvk = g_pool[:, subs_pos_vk]                               # (T, V, K)
    w_tvk = w_pool[:, subs_pos_vk]
    scores = _score_vertices(g_tvk, w_tvk, pmax, noise_power, scorer)  # (T, V)
    valid_v = subs_pos_vk[:, -1] < n_valid          # positions ascending per row
    ok = valid_v[None, :] & jnp.logical_not(done_t)[:, None]
    flat = jnp.where(ok, scores, -jnp.inf).reshape(-1)
    idx = jnp.argmax(flat)                          # first max: t-major order
    val = flat[idx]
    if axis_name is None:
        t_star = idx // v_cnt
        sub_ids = kept_sorted[t_star, subs_pos_vk[idx % v_cnt]]  # (K,)
        return val, t_star, sub_ids
    # Sharded combine: the local argmax is the shard's minimal global flat
    # index among its maxima (local and global flat orders agree within a
    # shard), so pmin over index candidates recovers the global first max.
    t_local = idx // v_cnt
    v_local = idx % v_cnt
    v_total = v_cnt * n_shards
    shard = jax.lax.axis_index(axis_name)
    gidx = t_local * v_total + shard * v_cnt + v_local
    vmax = jax.lax.pmax(val, axis_name)
    sentinel = jnp.asarray(t_cnt * v_total, gidx.dtype)
    cand = jnp.where(val == vmax, gidx, sentinel)
    gbest = jax.lax.pmin(cand, axis_name)
    t_star = gbest // v_total
    sub_local = kept_sorted[t_local, subs_pos_vk[v_local]]
    # (t, shard, v_local) -> gidx is injective, so exactly one shard owns
    # gbest; a psum of the masked ids is a one-hot gather across the mesh.
    mine = cand == gbest
    sub_ids = jax.lax.psum(jnp.where(mine, sub_local, 0), axis_name)
    return vmax, t_star, sub_ids


@functools.partial(
    jax.jit, static_argnames=("pool", "pmax", "noise_power")
)
def greedy_step(
    gains_tm: jax.Array,     # (T, M) channel gains, whole horizon
    weights_m: jax.Array,    # (M,) device weights
    solo_tm: jax.Array,      # (T, M) solo-rate pool-ranking proxy (host f64)
    subs_pos_vk: jax.Array,  # (V, K) int32 subsets as pool *positions*, lex order
    avail_m: jax.Array,      # (M,) bool: device not yet scheduled
    done_t: jax.Array,       # (T,) bool: round already assigned
    *,
    pool: int,
    pmax: float,
    noise_power: float,
):
    """One GWMIN greedy step: argmax-weight (subset, round) vertex on device.

    See :func:`_select_vertex` for the pool ranking / masking / tie-break
    rules.  ``pool`` is clamped to M like the host driver clamps
    ``candidate_pool`` — a caller passing ``pool > M`` gets the full-cell
    enumeration semantics instead of a shape error; subsets whose positions
    reach past the clamped pool are masked infeasible.

    Returns (best_val, t_star, subset_device_ids, avail_new, done_new); a
    best_val of -inf means no feasible vertex (caller stops or falls back to
    the host tail path for leftover groups smaller than K).
    """
    pool = min(pool, gains_tm.shape[1])
    val, t_star, sub_ids = _select_vertex(
        gains_tm, weights_m, solo_tm, subs_pos_vk, avail_m, done_t,
        pool=pool, pmax=pmax, noise_power=noise_power,
    )
    feasible = val > -jnp.inf
    # Out-of-range sentinel scatters are dropped by jax; the where() guards
    # the infeasible case anyway.
    avail_new = jnp.where(feasible, avail_m.at[sub_ids].set(False), avail_m)
    done_new = jnp.where(feasible, done_t.at[t_star].set(True), done_t)
    return val, t_star, sub_ids, avail_new, done_new


def _fused_loop(
    gains_tm, weights_m, solo_tm, subs_pos_vk,
    *, pool: int, pmax: float, noise_power: float, scorer: str,
    axis_name: str | None = None, n_shards: int = 1,
):
    """The whole greedy selection loop as one ``lax.while_loop`` (see module
    docstring for the carry layout).  Shared by the single-device jit and
    each ``shard_map`` shard — under sharding the collectives inside
    ``_select_vertex`` make every element of the carry replicated, so all
    shards run identical trip counts."""
    t_cnt, m = gains_tm.shape
    kk = subs_pos_vk.shape[1]
    max_steps = min(t_cnt, m // kk)   # static: the step-wise driver's
                                      # `avail_count >= kk` bound

    def cond(carry):
        step, feasible, _avail, _done, _assign = carry
        return (step < max_steps) & feasible

    def body(carry):
        step, _feasible, avail, done, assign = carry
        val, t_star, sub_ids = _select_vertex(
            gains_tm, weights_m, solo_tm, subs_pos_vk, avail, done,
            pool=pool, pmax=pmax, noise_power=noise_power, scorer=scorer,
            axis_name=axis_name, n_shards=n_shards,
        )
        feasible = val > -jnp.inf
        avail = jnp.where(feasible, avail.at[sub_ids].set(False), avail)
        done = jnp.where(feasible, done.at[t_star].set(True), done)
        assign = jnp.where(
            feasible, assign.at[t_star].set(sub_ids.astype(assign.dtype)), assign
        )
        return (step + jnp.int32(1), feasible, avail, done, assign)

    init = (
        jnp.int32(0),
        jnp.asarray(True),
        jnp.ones(m, bool),
        jnp.zeros(t_cnt, bool),
        jnp.full((t_cnt, kk), -1, jnp.int32),
    )
    _steps, _feasible, avail, done, assign = jax.lax.while_loop(cond, body, init)
    return assign, done, avail


@functools.partial(
    jax.jit, static_argnames=("pool", "pmax", "noise_power", "scorer")
)
def _fused_single(gains_tm, weights_m, solo_tm, subs_pos_vk,
                  *, pool, pmax, noise_power, scorer):
    return _fused_loop(
        gains_tm, weights_m, solo_tm, subs_pos_vk,
        pool=pool, pmax=pmax, noise_power=noise_power, scorer=scorer,
    )


@functools.lru_cache(maxsize=None)
def _fused_sharded(shards: int, pool: int, pmax: float, noise_power: float,
                   scorer: str):
    """Build (and cache) the jitted shard_map'd fused loop for a mesh of
    ``shards`` local devices.  The whole while_loop runs inside shard_map:
    only the subset enumeration is sharded; gains/weights/solo and the
    carry are replicated (the in-mesh argmax reduction keeps them so)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding import vertex as vertex_lib

    mesh = vertex_lib.vertex_mesh(shards)
    axis = vertex_lib.VERTEX_AXIS

    def fn(gains_tm, weights_m, solo_tm, subs_local):
        return _fused_loop(
            gains_tm, weights_m, solo_tm, subs_local,
            pool=pool, pmax=pmax, noise_power=noise_power, scorer=scorer,
            axis_name=axis, n_shards=shards,
        )

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis, None)),
        out_specs=(P(), P(), P()),
        check_rep=False,
    ))


def greedy_rounds_fused(
    gains_tm: jax.Array,     # (T, M) channel gains, whole horizon
    weights_m: jax.Array,    # (M,) device weights
    solo_tm: jax.Array,      # (T, M) solo-rate pool-ranking proxy (host f64)
    subs_pos_vk: jax.Array,  # (V, K) int32 subsets as pool *positions*, lex order
    *,
    pool: int,
    pmax: float,
    noise_power: float,
    scorer: str = "xla",
    shards: int | None = None,
):
    """Run the entire GWMIN greedy selection on device; sync-free until the
    caller reads the result (one host sync per schedule).

    Returns ``(assign_tk, done_t, avail_m)``: the (T, K) int32 assignment
    tensor (-1 where unassigned; rows with ``done_t`` hold exactly K device
    ids), the completed-round mask, and the still-available-device mask the
    host tail path resumes from when T*K > M.

    ``scorer`` picks the vertex scorer ("xla" | "pallas"); ``shards=N``
    shards the V axis over min(N, local_device_count()) devices via
    ``shard_map`` (see module docstring).  ``pool`` must already be clamped
    to M by the caller (the scheduling driver does) so the position
    enumeration matches the ranked pools.
    """
    if scorer not in SCORERS:
        raise ValueError(f"unknown scorer {scorer!r}; known: {SCORERS}")
    if shards is None:
        return _fused_single(
            gains_tm, weights_m, solo_tm, subs_pos_vk,
            pool=pool, pmax=pmax, noise_power=noise_power, scorer=scorer,
        )
    from repro.sharding import vertex as vertex_lib

    n = max(1, min(int(shards), vertex_lib.max_vertex_shards()))
    pad = vertex_lib.pad_rows_to_multiple(subs_pos_vk.shape[0], n)
    if pad:
        # Sentinel rows point at position ``pool``: past every ranked pool,
        # so ``valid_v`` masks them infeasible on every shard.
        subs_pos_vk = jnp.concatenate([
            subs_pos_vk,
            jnp.full((pad, subs_pos_vk.shape[1]), pool, subs_pos_vk.dtype),
        ])
    fn = _fused_sharded(n, pool, float(pmax), float(noise_power), scorer)
    return fn(gains_tm, weights_m, solo_tm, subs_pos_vk)
