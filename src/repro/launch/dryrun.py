import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) and emit
# memory / cost / roofline analysis — run as
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]
#
# The XLA_FLAGS lines above MUST precede any jax import: jax locks the device
# count at first init (MULTI-POD DRY-RUN step 0). Do not import this module
# from tests — they should see 1 device.
import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.configs import ARCH_IDS, get_config
from repro.launch import roofline as rl
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models import layers as mlayers
from repro.models.params import abstract_params
from repro.optim import adamw
from repro.sharding import rules as sh

# Principled skips (DESIGN.md §4): long_500k needs sub-quadratic attention.
SKIPS = {
    ("qwen3_8b", "long_500k"): "pure full attention",
    ("granite_34b", "long_500k"): "pure full attention",
    ("qwen2_0_5b", "long_500k"): "pure full attention",
    ("mistral_large_123b", "long_500k"): "pure full attention",
    ("llama_3_2_vision_90b", "long_500k"): "pure full-attention backbone",
    ("seamless_m4t_medium", "long_500k"): "enc-dec; 500k decode not meaningful",
}


def _batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, batch, mesh):
    out = {}
    for k, v in batch.items():
        out[k] = sh.activation_specs(mesh, v.shape[0], extra_dims=v.ndim - 1)
    return out


def _cache_pspecs(abstract_caches, mesh):
    def spec(x):
        if x.ndim >= 4:
            # (stack..., B, S, H, D) KV caches
            return sh.cache_pspec(mesh, x.shape, stacked_dims=x.ndim - 4)
        if x.ndim == 0 or x.shape == ():
            return P()
        # SSM/conv states: (stack..., B, ...) — shard batch when divisible
        ba = sh.batch_axes(mesh)
        nb = sh.batch_shard(mesh)
        for i, d in enumerate(x.shape):
            if d % nb == 0 and d >= nb:
                return P(*([None] * i), ba, *([None] * (x.ndim - i - 1)))
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map(spec, abstract_caches)


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    status: str
    compile_s: float = 0.0
    bytes_per_device: int = 0
    roofline: dict = None
    error: str = ""


def probe_plan(cfg):
    """Reduced-config probes for component-wise FLOP extrapolation.

    Returns (probes, target): each probe is (cfg-overrides, counts) where
    counts are the multiplicities of each homogeneous component
    (intercept, unit1[, unit2]) in that probe; `target` is the full
    config's multiplicities. Per-chip FLOPs/bytes/collective-bytes are
    linear in these counts, so a least-squares fit over the probes
    evaluates the full config without ever compiling it unrolled."""
    if cfg.family == "hybrid":
        # components: intercept, mamba layer, shared-attn site
        probes = [
            ({"num_layers": 3, "hybrid_attn_every": 2}, (1, 3, 1)),
            ({"num_layers": 2, "hybrid_attn_every": 2}, (1, 2, 1)),
            ({"num_layers": 4, "hybrid_attn_every": 2}, (1, 4, 2)),
        ]
        target = (1, cfg.num_layers, cfg.num_layers // cfg.hybrid_attn_every)
    elif cfg.family == "vlm":
        # components: intercept, self layer, cross layer
        probes = [
            ({"num_layers": 2, "cross_attn_every": 2}, (1, 1, 1)),
            ({"num_layers": 4, "cross_attn_every": 2}, (1, 2, 2)),
            ({"num_layers": 4, "cross_attn_every": 4}, (1, 3, 1)),
        ]
        e = cfg.cross_attn_every
        target = (1, cfg.num_layers - cfg.num_layers // e, cfg.num_layers // e)
    elif cfg.family == "encdec":
        probes = [
            ({"num_layers": 2, "encoder_layers": 2}, (1, 2)),
            ({"num_layers": 4, "encoder_layers": 4}, (1, 4)),
        ]
        target = (1, cfg.num_layers)
    else:
        probes = [({"num_layers": 2}, (1, 2)), ({"num_layers": 4}, (1, 4))]
        target = (1, cfg.num_layers)
    return probes, target


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            unroll: bool = True, fl_bits: int | None = 8,
            kv_chunk_train: int = 1024, kv_chunk_decode: int = 4096,
            cfg_override: dict | None = None, grad_accum: int = 1,
            remat: bool = True,
            verbose: bool = True) -> DryrunResult:
    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    model = build_model(cfg, shards=mesh.shape["model"])
    ab_params = abstract_params(model.schema)
    pspecs = sh.param_pspecs(model.param_logical_specs(), ab_params, mesh)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    ab_params = jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        ab_params, pshard,
    )

    batch = steps.input_specs(cfg, shape)
    bspecs = _batch_pspecs(cfg, shape, batch, mesh)
    batch = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                sharding=NamedSharding(mesh, bspecs[k]))
        for k, v in batch.items()
    }

    def ns(tree):
        return jax.tree_util.tree_map(
            lambda x: x if isinstance(x, NamedSharding) else NamedSharding(mesh, x),
            tree, is_leaf=lambda x: isinstance(x, P),
        )

    ba = sh.batch_axes(mesh)
    nb = sh.batch_shard(mesh)
    nm = mesh.shape["model"]

    def act_hook(x, kind):
        # pin the canonical megatron layout; skip when dims don't divide
        batch_ok = x.shape[0] % nb == 0
        if kind == "residual" and x.ndim == 3:
            spec = P(ba if batch_ok else None, None, None)
        elif kind == "heads" and x.ndim == 4:
            heads_ok = x.shape[2] % nm == 0
            spec = P(ba if batch_ok else None, None,
                     "model" if heads_ok else None, None)
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    t0 = time.time()
    try:
        mlayers.set_activation_sharding(act_hook)
        with mesh:
            if shape.kind == "train":
                opt = adamw(3e-4)
                ab_opt = jax.eval_shape(opt.init, ab_params)
                ospecs = {
                    k: (P() if k == "step" else pspecs)
                    for k in ab_opt.keys()
                }
                step = steps.make_train_step(
                    model, opt, fl_bits=fl_bits, unroll=unroll,
                    kv_chunk=kv_chunk_train, grad_accum=grad_accum,
                    remat=remat,
                )
                lowered = jax.jit(
                    step,
                    in_shardings=ns((pspecs, ospecs, bspecs)),
                    out_shardings=ns((pspecs, ospecs, P())),
                ).lower(ab_params, ab_opt, batch)
            elif shape.kind == "prefill":
                step = steps.make_prefill_step(
                    model, shape, unroll=unroll, kv_chunk=kv_chunk_train
                )
                ab_caches = steps.abstract_cache(model, shape)
                cspecs = _cache_pspecs(ab_caches, mesh)
                out_logits = sh.activation_specs(mesh, shape.global_batch,
                                                 extra_dims=2)
                lowered = jax.jit(
                    step,
                    in_shardings=ns((pspecs, bspecs)),
                    out_shardings=ns((out_logits, cspecs)),
                ).lower(ab_params, batch)
            else:  # decode
                step = steps.make_serve_step(
                    model, unroll=unroll, kv_chunk=kv_chunk_decode
                )
                ab_caches = steps.abstract_cache(model, shape)
                cspecs = _cache_pspecs(ab_caches, mesh)
                ab_caches = jax.tree_util.tree_map(
                    lambda a, s: jax.ShapeDtypeStruct(
                        a.shape, a.dtype, sharding=NamedSharding(mesh, s)),
                    ab_caches, cspecs,
                )
                tok_spec = sh.activation_specs(mesh, shape.global_batch,
                                               extra_dims=1)
                lowered = jax.jit(
                    step,
                    in_shardings=ns((pspecs, cspecs, bspecs)),
                    out_shardings=ns((tok_spec, cspecs)),
                ).lower(ab_params, ab_caches, batch)

            compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001 — dry-run failures are findings
        return DryrunResult(arch, shape_name, mesh_name, "FAIL",
                            time.time() - t0, error=f"{type(e).__name__}: {e}")
    finally:
        mlayers.set_activation_sharding(None)

    dt = time.time() - t0
    mem = compiled.memory_analysis()
    bytes_per_device = int(
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        - mem.alias_size_in_bytes + mem.temp_size_in_bytes
    )
    hlo = compiled.as_text()
    roof = rl.analyze(compiled, hlo, cfg, shape, n_chips=n_chips)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compile {dt:.1f}s  "
              f"mem/dev {bytes_per_device/2**30:.2f} GiB  "
              f"bottleneck {roof.bottleneck}  "
              f"t=(c {roof.t_compute*1e3:.2f} | m {roof.t_memory*1e3:.2f} | "
              f"x {roof.t_collective*1e3:.2f}) ms  "
              f"useful {roof.useful_flops_ratio:.2f}")
        sys.stdout.flush()
    return DryrunResult(arch, shape_name, mesh_name, "OK", dt,
                        bytes_per_device, roof.summary())


def roofline_extrapolated(arch: str, shape_name: str, *, fl_bits: int | None = 8,
                          grad_accum: int = 1, cfg_override: dict | None = None,
                          verbose: bool = True,
                          **run_kw) -> DryrunResult:
    """Component-extrapolated roofline (EXPERIMENTS.md §Roofline).

    Full-depth unrolled compiles are infeasible on the CPU container (hours
    per pair), so each pair is compiled UNROLLED at 2-3 reduced configs
    (probe_plan) and the per-chip FLOPs / bytes / collective-bytes are
    solved component-wise (least squares, exact for these probe designs)
    and evaluated at the full config. The full-depth *scanned* compile (the
    ordinary dry-run) separately proves lowering/sharding/memory."""
    import numpy as np

    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    probes, target = probe_plan(cfg)
    results = []
    for overrides, counts in probes:
        r = run_one(arch, shape_name, unroll=True,
                    cfg_override={**(cfg_override or {}), **overrides},
                    fl_bits=fl_bits, grad_accum=grad_accum, verbose=False,
                    **run_kw)
        if r.status != "OK":
            return dataclasses.replace(r, mesh=r.mesh + "(extrap)")
        results.append((r, counts))

    shape = INPUT_SHAPES[shape_name]
    a_mat = np.array([c for _, c in results], dtype=np.float64)
    tvec = np.array(target, dtype=np.float64)

    def extrap(key):
        y = np.array([r.roofline[key] for r, _ in results])
        coef, *_ = np.linalg.lstsq(a_mat, y, rcond=None)
        val = float(tvec @ coef)
        return max(val, float(y.max()))

    flops = extrap("hlo_flops_per_chip")
    hbm = extrap("hbm_bytes_per_chip")
    coll = extrap("collective_bytes_per_chip")
    mf = rl.model_flops(cfg, shape, n_chips=256)
    terms = {
        "t_compute_s": flops / rl.PEAK_FLOPS,
        "t_memory_s": hbm / rl.HBM_BW,
        "t_collective_s": coll / rl.LINK_BW,
    }
    bottleneck = max(terms, key=terms.get).replace("t_", "").replace("_s", "")

    def extrap_coll(kind):
        y = np.array([r.roofline["collective_breakdown"][kind]
                      for r, _ in results])
        coef, *_ = np.linalg.lstsq(a_mat, y, rcond=None)
        return max(float(tvec @ coef), 0.0)

    summary = {
        **terms,
        "bottleneck": bottleneck,
        "hlo_flops_per_chip": flops,
        "hbm_bytes_per_chip": hbm,
        "collective_bytes_per_chip": coll,
        "collective_breakdown": {
            k: extrap_coll(k)
            for k in results[0][0].roofline["collective_breakdown"]
        },
        "collective_counts": results[-1][0].roofline["collective_counts"],
        "model_flops_per_chip": mf,
        "useful_flops_ratio": mf / max(flops, 1.0),
        "probe_configs": [o for o, _ in probes],
        "target_counts": list(target),
    }
    res = DryrunResult(arch, shape_name, results[0][0].mesh + "(extrap)", "OK",
                       sum(r.compile_s for r, _ in results),
                       results[-1][0].bytes_per_device, summary)
    if verbose:
        print(f"[{arch} x {shape_name} x roofline-extrap] "
              f"bottleneck {bottleneck}  "
              f"t=(c {terms['t_compute_s']*1e3:.2f} | m {terms['t_memory_s']*1e3:.2f} | "
              f"x {terms['t_collective_s']*1e3:.2f}) ms  "
              f"useful {summary['useful_flops_ratio']:.2f}")
        sys.stdout.flush()
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep layer scans rolled (faster compile, FLOPs undercounted)")
    ap.add_argument("--roofline", action="store_true",
                    help="depth-extrapolated roofline pass (reduced-depth unrolled)")
    ap.add_argument("--fl-bits", type=int, default=8,
                    help="paper's uplink quantization bit-width in train_step (32=off)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatch count for train shapes (memory lever)")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs.append((args.arch, args.shape))

    results = []
    for arch, shape in pairs:
        from repro.configs import canonical

        if (canonical(arch), shape) in SKIPS:
            print(f"[{arch} x {shape}] SKIP: {SKIPS[(canonical(arch), shape)]}")
            res = DryrunResult(arch, shape, "-", "SKIP",
                               error=SKIPS[(canonical(arch), shape)])
            results.append(res)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(dataclasses.asdict(res)) + "\n")
            continue
        if args.roofline:
            res = roofline_extrapolated(arch, shape, fl_bits=args.fl_bits,
                                        grad_accum=args.grad_accum)
        else:
            res = run_one(arch, shape, multi_pod=args.multi_pod,
                          unroll=not args.no_unroll, fl_bits=args.fl_bits,
                          grad_accum=args.grad_accum)
        if res.status == "FAIL":
            print(f"[{arch} x {shape}] FAIL: {res.error}")
        results.append(res)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(dataclasses.asdict(res)) + "\n")

    n_ok = sum(r.status == "OK" for r in results)
    n_fail = sum(r.status == "FAIL" for r in results)
    n_skip = sum(r.status == "SKIP" for r in results)
    print(f"\n== dry-run: {n_ok} OK, {n_fail} FAIL, {n_skip} SKIP ==")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
