"""NOMA/SIC properties (paper Eq. 4-6), incl. hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.core import noma

NOISE = 1e-13


def _gains(k, seed=0):
    return np.abs(np.random.default_rng(seed).normal(1e-6, 5e-7, k)) + 1e-8


def test_sic_sum_rate_identity():
    """Fundamental SIC identity: sum_k log2(1+SINR_k) == log2(1 + sum rx / sigma^2).

    Successive cancellation makes the (unweighted) sum rate equal the
    multiple-access-channel capacity, independent of decode order."""
    g = jnp.asarray(_gains(4))
    p = jnp.full(4, 0.01)
    rates = noma.rates(p, g, NOISE)
    total_rx = jnp.sum(p * g**2)
    np.testing.assert_allclose(
        float(jnp.sum(rates)), float(jnp.log2(1 + total_rx / NOISE)), rtol=1e-5
    )


@settings(max_examples=50, deadline=None)
@given(
    st.integers(2, 5),
    st.integers(0, 2**31 - 1),
)
def test_sic_sum_rate_identity_property(k, seed):
    g = jnp.asarray(_gains(k, seed))
    p = jnp.asarray(np.random.default_rng(seed + 1).uniform(1e-4, 0.01, k))
    rates = noma.rates(p, g, NOISE)
    total_rx = jnp.sum(p * g**2)
    np.testing.assert_allclose(
        float(jnp.sum(rates)),
        float(jnp.log2(1 + total_rx / NOISE)),
        rtol=1e-4,
    )


def test_sinr_strongest_decoded_first():
    g = jnp.asarray(_gains(3))
    p = jnp.full(3, 0.01)
    rx = np.asarray(p * g**2)
    s = np.asarray(noma.sinr(p, g, NOISE))
    strongest = int(np.argmax(rx))
    weakest = int(np.argmin(rx))
    # strongest sees all others as interference; weakest sees none
    assert s[strongest] == pytest.approx(
        rx[strongest] / (rx.sum() - rx[strongest] + NOISE), rel=1e-5
    )
    assert s[weakest] == pytest.approx(rx[weakest] / NOISE, rel=1e-5)


def test_rates_permutation_equivariant():
    g = _gains(4)
    p = np.random.default_rng(1).uniform(1e-3, 0.01, 4)
    r = np.asarray(noma.rates(jnp.asarray(p), jnp.asarray(g), NOISE))
    perm = np.array([2, 0, 3, 1])
    r2 = np.asarray(noma.rates(jnp.asarray(p[perm]), jnp.asarray(g[perm]), NOISE))
    np.testing.assert_allclose(r[perm], r2, rtol=1e-5)


def test_tdma_rates_exceed_noma_per_user():
    """Without interference each user's rate can only go up."""
    g = jnp.asarray(_gains(3))
    p = jnp.full(3, 0.01)
    assert bool(jnp.all(noma.tdma_rates(p, g, NOISE) >= noma.rates(p, g, NOISE) - 1e-9))


def test_bit_budget_scales_with_bandwidth_and_time():
    g = jnp.asarray(_gains(2))
    p = jnp.full(2, 0.01)
    b1 = noma.bit_budget(p, g, NOISE, 4e6, 0.2)
    b2 = noma.bit_budget(p, g, NOISE, 8e6, 0.1)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=1e-6)
