from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    momentum,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
