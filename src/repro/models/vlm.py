"""Llama-3.2-Vision-style VLM backbone: a dense decoder LM with gated
cross-attention image layers interleaved every ``cross_attn_every`` layers
[hf:meta-llama/Llama-3.2-11B-Vision].

Per the assignment carve-out, the vision tower is a STUB: ``img_feats``
arrives as pre-projected patch embeddings (B, num_image_tokens, d_model)
from ``input_specs()``. The backbone implements the language side: sites of
(cross_attn_every - 1) self-attention layers followed by one tanh-gated
cross-attention layer (gates init 0 => identity at init, as in the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import ParamSpec, stacked


def sites_of(cfg):
    every = cfg.cross_attn_every
    assert every and cfg.num_layers % every == 0
    return cfg.num_layers // every, every - 1


def cross_block_schema(cfg, *, shards: int = 16):
    return {
        "ln_q": L.rmsnorm_schema(cfg.d_model),
        "ln_kv": L.rmsnorm_schema(cfg.d_model),
        "attn": L.attention_schema(cfg, shards=shards),
        "gate_attn": ParamSpec((), (), init="zeros"),
        "ln2": L.rmsnorm_schema(cfg.d_model),
        "mlp": L.mlp_schema(cfg.d_model, cfg.d_ff),
        "gate_mlp": ParamSpec((), (), init="zeros"),
    }


def schema(cfg, *, shards: int = 16):
    n_sites, self_per = sites_of(cfg)
    return {
        "embed": L.embedding_schema(cfg.padded_vocab, cfg.d_model, tie=cfg.tie_embeddings),
        "self_layers": stacked(stacked(T.block_schema(cfg, shards=shards), self_per), n_sites),
        "cross_layers": stacked(cross_block_schema(cfg, shards=shards), n_sites),
        "ln_f": L.rmsnorm_schema(cfg.d_model),
    }


def cross_block(p, x, img, cfg, *, kv_chunk):
    h, _ = L.attention_block(
        p["attn"], L.rmsnorm(p["ln_q"], x, cfg.norm_eps), cfg,
        mask_spec=L.AttnMaskSpec(causal=False),
        kv_source=L.rmsnorm(p["ln_kv"], img, cfg.norm_eps),
        kv_chunk=kv_chunk,
    )
    x = x + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * h
    m = L.mlp_block(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * m


def forward(params, tokens, cfg, *, img_feats, caches=None,
            kv_chunk: int = 1024, remat: bool = True, unroll: bool = False, **_):
    x = L.embed(params["embed"], tokens)
    mspec = L.AttnMaskSpec(causal=True)
    positions = None
    if caches is not None:
        positions = caches["len"][0, 0] + jnp.arange(tokens.shape[1])[None, :]

    def self_stack(x, p_stack, cache_stack):
        def body(x, xs):
            p_layer, cache = xs
            return T.transformer_block(
                p_layer, x, cfg, mspec=mspec, positions=positions,
                cache=cache, kv_chunk=kv_chunk,
            )

        fn = jax.checkpoint(body) if (remat and caches is None) else body
        return jax.lax.scan(fn, x, (p_stack, cache_stack), unroll=unroll)

    def site_body(x, xs):
        p_self, p_cross, cache_stack = xs
        x, new_caches = self_stack(x, p_self, cache_stack)
        x = cross_block(p_cross, x, img_feats, cfg, kv_chunk=kv_chunk)
        return x, new_caches

    x, new_caches = jax.lax.scan(
        site_body, x, (params["self_layers"], params["cross_layers"], caches),
        unroll=unroll,
    )
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, tie=cfg.tie_embeddings)
    return logits, new_caches


def loss_fn(params, batch, cfg, **kw):
    logits, _ = forward(params, batch["tokens"], cfg,
                        img_feats=batch["img_feats"], **kw)
    return L.cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int, *, shards: int = 16):
    n_sites, self_per = sites_of(cfg)
    one = L.init_attn_cache(cfg, batch, max_len, shards=shards)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None, None], (n_sites, self_per, *x.shape)), one
    )


def decode_step(params, caches, tokens, cfg, *, img_feats, kv_chunk: int = 4096,
                unroll: bool = False):
    logits, new_caches = forward(
        params, tokens, cfg, img_feats=img_feats, caches=caches,
        kv_chunk=kv_chunk, remat=False, unroll=unroll,
    )
    return logits, new_caches
