"""User scheduling policies for FL over NOMA (paper §III + online variants).

Every scheduler is a **policy** behind one protocol (:class:`SchedulerPolicy`):

    state = policy.init_state(gains_tm, weights_m, cfg)          # once
    group, state = policy.select_round(t, state, obs)            # per round

``cfg`` is a :class:`PolicyConfig` (group size K, power mode, cell physics,
seed); ``obs`` is an :class:`Observation` carrying the *online* observables —
previous-round local-update norms, per-device participation counts /
last-participation ages, and realized uplink rates.  Policies come in two
flavours:

  * **precomputed** (``online = False``): device selection depends only on
    the channel realizations, so ``init_state`` plans the whole T-round
    horizon up front (the paper's setting).  ``select_round`` just replays
    the plan and ignores ``obs``.
  * **online** (``online = True``): selection reads FL state from ``obs``
    round by round; ``fl.run_federated_learning`` calls ``select_round``
    *inside* the training loop (live mode) and feeds the realized norms /
    rates back.  Online policies may re-schedule a device across rounds
    (``respects_c1 = False``) — they trade the paper's one-shot C1
    constraint for long-horizon participation control.

Online policies may additionally implement the **traced protocol**
(``traced_protocol = True`` plus ``init_traced`` / ``select_round_traced``):
a jnp mirror of ``select_round`` that runs *inside* the scanned-horizon
round body (``fl_engine._online_horizon_core``), reading a
:class:`TracedObservation` threaded through the ``lax.scan`` carry — the
whole feedback loop stays on device, so ``FLConfig.horizon = "scan"``
accepts the policy (config validation asks :func:`policy_is_traced`).
All three registered online policies implement it.

Policies are looked up by name through a registry (:func:`register_policy` /
:func:`get_policy`); power allocation and rate computation live in one shared
finalization step (:func:`finalize_schedule` for full horizons,
:func:`finalize_round` for live mode) built on
:class:`repro.core.power.PowerAllocator`.

Registered policies
-------------------
  * ``lazy-gwmin`` — graph-free Algorithm 2 (GWMIN MWIS greedy); numpy or
    device-resident jax backend.  The paper's proposed scheduler.
  * ``literal-gwmin`` — Algorithm 2 on the explicit C(M,K)*T-vertex graph
    (exact fidelity, exponential memory; M up to ~12).
  * ``random`` / ``round-robin`` / ``proportional-fair`` — the §IV / ref [6]
    baselines (PF ranks by the weighted solo rate w_k R_k; ``by_gain=True``
    reproduces the seed's raw-gain ranking).
  * ``update-aware`` — online; scores devices by ‖ΔW_k‖ · solo rate
    (Amiri et al., arXiv:2001.10402) so informative *and* fast uplinks win.
  * ``age-fair`` — online; staleness-boosted weighted rates
    (1 + age_k) · w_k R_k (Yang et al., arXiv:1908.06287) so no device
    starves over long horizons.
  * ``matching-pursuit`` — online; greedily grows the round's device set by
    residual aggregation-error decrease (the OTA companion policy: omitted
    devices cost their weighted update energy, admitted devices pay the
    channel-inversion noise penalty lambda * max (w n / h)^2 with
    lambda = ota_noise^2 / pmax).  With ``ota_noise = 0`` it degenerates
    to top-K by weighted update norm.

How to add a policy
-------------------
1. Write a class with ``init_state`` / ``select_round`` (subclass
   ``_PrecomputedPolicy`` for offline plans or ``_ScoreTopKPolicy`` for
   online top-K scoring rules — then it is one ``_plan`` / ``_score``
   method).  Declare ``online`` and ``respects_c1`` (and, for online
   policies, ``needs_norms`` — whether the FL loop should compute
   per-device update norms for you; it defaults to True when absent).
   To run under ``horizon="scan"`` an online policy also implements the
   traced protocol (``_ScoreTopKPolicy`` subclasses inherit it from a
   jnp ``_score_traced`` mirror of ``_score``); without it the scanned
   driver keeps rejecting the policy with the pinned error.
2. Decorate it with ``@register_policy("my-policy")``.  The name becomes a
   valid ``FLConfig.scheduler`` immediately (config validation reads the
   registry), and ``benchmarks/fig6_schemes.py`` can sweep it by name.
3. If it is online, return groups from ``select_round`` using only
   ``state`` + ``obs``; the runtime owns power allocation and rates via the
   shared finalization (never allocate powers inside a policy).

MWIS formulation (paper §III-A)
-------------------------------
A vertex v = (S, t) is a K-subset S proposed for round t; edges connect
vertices violating C1 (shared device, t_i != t_j) or C2 (t_i == t_j).  An
independent set with T vertices is a complete schedule; vertex weight
w(v) = sum_{k in S} w_k R_k^t makes the MWIS the max-weighted-sum-rate
schedule (Eq. 9-10).

Equivalence note (DESIGN.md §6.3): in the residual graph after any number of
GWMIN removals, the remaining vertex set is always {all K-subsets of unused
devices} x {remaining rounds}, and every vertex has the *same* degree
beta = (C(A,K)-1) + (T_rem-1) * (C(A,K) - C(A-K,K)), where A = #unused
devices. With uniform degrees, argmax_{v in Q} w(v)/(beta(v)+1) reduces to
argmax_v w(v) (the global max-weight vertex is always in Q since
sum_{u in J(v)} w(u)/(beta+1) <= beta*w(v)/(beta+1) + w(v)/(beta+1) = w(v)).
So Algorithm 2 == repeatedly take the max-weight (subset, round) among unused
devices and remaining rounds. ``tests/test_scheduling.py`` checks the two
produce identical schedules on instances where the literal graph fits.

Backends: ``lazy_greedy_schedule(backend="numpy")`` (default) walks rounds in
Python and scores each round's candidate batch with the numpy engine;
``backend="jax"`` runs the **entire** selection loop on device as one jitted
``lax.while_loop`` (``repro.core.rates_jax.greedy_rounds_fused``): the
C(pool, K) subset enumeration is built once as *positions* into a per-round
candidate pool, the loop carries ``(step, feasible, avail, done, assign)``
on device, every iteration re-masks availability, re-ranks the pools, scores
the full (T, V, K) vertex tensor, and writes the argmax vertex into the
(T, K) assignment tensor, and the host syncs exactly once per schedule.
Two fused-backend switches: ``scorer="xla" | "pallas"`` picks the vertex
scorer (XLA comparison-matrix vs the Pallas SIC kernel of
``repro.kernels.sic_rates``) and ``shards=N`` shards the subset axis over N
local devices via ``shard_map`` with an in-mesh argmax reduction
(``repro.sharding.vertex``).  ``backend="jax-stepwise"`` keeps the previous
driver — one jitted ``greedy_step`` call (and one host sync) per greedy
step.  All backends produce bit-identical schedules (same stable
tie-breaking: earliest round, lexicographically-first subset, ties in the
pool ranking to the lower device id); leftover tail groups smaller than K
fall back to the host path.  Power refinement with ``power_mode="mapel"``
is batched over all selected groups at the end (``power.mapel_batched``)
instead of solved round-by-round.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, NamedTuple, Protocol, Sequence

import numpy as np

from repro.core import power as power_lib
from repro.core import rates as rates_lib

PowerFn = Callable[[np.ndarray, np.ndarray], np.ndarray]
# (gains_K, weights_K) -> powers_K; may carry a ``batched`` attribute
# (gains_VK, weights_VK) -> powers_VK for vectorized candidate scoring.
# ``power.PowerAllocator`` satisfies this interface.

SCHEDULER_BACKENDS = ("numpy", "jax", "jax-stepwise")
# the lazy greedy's drivers (_lazy_gwmin_rounds); FLConfig validates
# ``scheduler_backend`` against this same tuple.  "jax" is the fused
# while_loop driver (one host sync per schedule); "jax-stepwise" keeps the
# one-jitted-call-per-greedy-step driver for comparison and benchmarks.


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

def make_power_fn(
    mode: str, pmax: float, noise_power: float
) -> power_lib.PowerAllocator:
    """Legacy-named front door to :class:`repro.core.power.PowerAllocator`.

    The allocator is callable and carries ``batched`` (an alias of
    ``solve_batched``), so it drops into every historical ``PowerFn`` call
    site; new code should use ``power.make_power_allocator`` directly.
    """
    return power_lib.make_power_allocator(mode, pmax, noise_power)


def _solo_proxy(gains, weights, pmax: float, noise_power: float) -> np.ndarray:
    """Pool-ranking proxy: weighted interference-free rate of each device
    alone.  Shared by the numpy per-round pool and the jax backend's
    precomputed (T, M) table — the backends' bit-equality rests on ranking
    from identical float64 values, so there is exactly one formula."""
    return weights * np.log2(1.0 + (pmax * gains**2) / noise_power)


def _batched_powers(power_fn: PowerFn, gains_vk, weights_vk) -> np.ndarray:
    """(V, K) powers for V candidate groups; row loop only for iterative
    allocators (MAPEL) that expose no vectorized form."""
    batched = getattr(power_fn, "batched", None)
    if batched is not None:
        return batched(gains_vk, weights_vk)
    return np.stack(
        [power_fn(g, w) for g, w in zip(gains_vk, weights_vk)]
    )


def score_subsets(
    subsets_vk: np.ndarray,
    t: int,
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    power_fn: PowerFn,
    noise_power: float,
) -> np.ndarray:
    """Weighted sum rate of every candidate group in one engine call.

    subsets_vk: (V, K) int array of device ids, one candidate K-subset per
    row, all proposed for round t. Replaces the seed's per-subset Python
    loop (one ``group_weighted_rate`` call per ``itertools.combinations``
    element) with a single (V, K) ``batched_weighted_rates`` evaluation.
    """
    if subsets_vk.size == 0:
        return np.zeros((len(subsets_vk),))
    g = gains_tm[t][subsets_vk]
    w = weights_m[subsets_vk]
    p = _batched_powers(power_fn, g, w)
    return rates_lib.batched_weighted_rates(p, g, w, noise_power)


def group_weighted_rate(
    subset: Sequence[int],
    t: int,
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    power_fn: PowerFn,
    noise_power: float,
):
    """Weighted sum rate (and powers, rates) of scheduling `subset` at round t."""
    idx = np.asarray(subset, dtype=np.intp)
    g = gains_tm[t, idx]
    w = weights_m[idx]
    p = power_fn(g, w)
    rates = rates_lib.sic_rates(p, g, noise_power)
    return float(np.sum(w * rates)), p, rates


def _rates(powers, gains, noise_power):
    """Thin wrapper kept for back-compat; the math lives in core.rates."""
    return rates_lib.sic_rates(powers, gains, noise_power)


def validate_group(group, num_devices: int, k: int, *, label: str = "group"):
    """One round's group invariants: size <= K, distinct, in-range ids.

    The single owner of the per-round rules — ``Schedule.validate`` applies
    it to every round and the live FL loop applies it to each group an
    online policy hands back.  Raises ValueError.
    """
    if (
        len(group) > k
        or len(set(group)) != len(group)
        or any(not 0 <= d < num_devices for d in group)
    ):
        raise ValueError(
            f"invalid {label} {tuple(group)}: at most K={k} distinct "
            f"device ids in [0, {num_devices})"
        )


@dataclasses.dataclass
class Schedule:
    """A complete schedule: device groups, powers and rates per round."""

    rounds: list            # list[T] of tuple[int, ...] device ids
    powers: list            # list[T] of np.ndarray (K,)
    rates: list             # list[T] of np.ndarray (K,) spectral efficiencies
    weighted_sum_rate: float
    method: str
    allow_revisits: bool = False   # True for schedules built by online
                                   # policies (respects_c1 = False)

    def scheduled_devices(self) -> set:
        return set(itertools.chain.from_iterable(self.rounds))

    def validate(self, num_devices: int, k: int, allow_revisits=None):
        """Assert constraints C2 (and C1 unless revisits are allowed) hold.

        ``allow_revisits=None`` defers to the schedule's own flag (set by
        ``build_schedule`` from the producing policy's ``respects_c1``).
        Online policies legitimately re-schedule devices across rounds;
        they still may not duplicate a device within a round or emit
        out-of-range ids.
        """
        if allow_revisits is None:
            allow_revisits = self.allow_revisits
        seen = set()
        for t, grp in enumerate(self.rounds):
            validate_group(grp, num_devices, k, label=f"round-{t} group")
            for d in grp:
                if not allow_revisits and d in seen:
                    raise ValueError(
                        f"C1 violated: device {d} scheduled again in round "
                        f"{t} (set allow_revisits for online-policy schedules)"
                    )
                seen.add(d)
        return True


def finalize_round(group, t, gains_tm, weights_m, power_fn, noise_power):
    """Power allocation + SIC rates for one scheduled group (live mode).

    The per-round twin of :func:`finalize_schedule`: online policies select
    a group inside the FL loop and the runtime finalizes it immediately —
    policies themselves never allocate power.  Returns ``(powers, rates)``,
    both (len(group),), input order.
    """
    idx = np.asarray(group, dtype=np.intp)
    if idx.size == 0:
        return np.zeros(0), np.zeros(0)
    g = gains_tm[t, idx]
    w = weights_m[idx]
    p = np.asarray(power_fn(g, w))
    r = rates_lib.sic_rates(p, g, noise_power)
    return p, r


def finalize_schedule(rounds, gains_tm, weights_m, power_fn, noise_power, method):
    """Powers/rates/weighted-sum for a complete schedule.

    The shared finalization step: every policy's selected rounds pass
    through here, so power allocation and rate computation have exactly one
    owner.  Groups are batched by size and handed to the allocator in one
    call per size (for MAPEL this is the batched polyblock refinement over
    all T selected groups — the per-round loop it replaces solved each
    group separately).  Tail groups smaller than K (T*K > M horizons) and
    empty rounds batch among themselves.
    """
    num_rounds = len(rounds)
    powers, rates = [None] * num_rounds, [None] * num_rounds
    vals = np.zeros(num_rounds)
    by_size = {}
    for t, grp in enumerate(rounds):
        by_size.setdefault(len(grp), []).append(t)
    for kk, ts in sorted(by_size.items()):
        idx = np.array([rounds[t] for t in ts], dtype=np.intp).reshape(len(ts), kk)
        g = gains_tm[np.asarray(ts, dtype=np.intp)[:, None], idx]
        w = weights_m[idx]
        if kk == 0:
            p = np.zeros((len(ts), 0))
        else:
            p = _batched_powers(power_fn, g, w)
        r = rates_lib.sic_rates(p, g, noise_power)
        for row, t in enumerate(ts):
            powers[t] = p[row]
            rates[t] = r[row]
            vals[t] = float(np.sum(w[row] * r[row]))
    total = 0.0
    for t in range(num_rounds):    # accumulate in round order (reproducible)
        total += float(vals[t])
    return Schedule(list(map(tuple, rounds)), powers, rates, total, method)


_finalize = finalize_schedule    # back-compat alias (pre-policy-API name)


# --------------------------------------------------------------------------
# Literal Algorithm 2 on the explicit scheduling graph
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SchedulingGraph:
    vertices: list          # list of (subset tuple, t)
    weights: np.ndarray     # (V,)
    adjacency: list         # list[V] of set[int]

    def degree(self, v: int) -> int:
        return len(self.adjacency[v])


def build_scheduling_graph(
    gains_tm: np.ndarray,
    weights_m: np.ndarray,
    k: int,
    power_fn: PowerFn,
    noise_power: float,
) -> SchedulingGraph:
    """Explicit graph with C(M,K)*T vertices (paper §III-A)."""
    num_rounds, num_devices = gains_tm.shape
    subsets = list(itertools.combinations(range(num_devices), k))
    vertices = [(subset, t) for t in range(num_rounds) for subset in subsets]
    subs_vk = np.array(subsets, dtype=np.intp).reshape(len(subsets), k)
    weights = np.concatenate(
        [
            score_subsets(subs_vk, t, gains_tm, weights_m, power_fn, noise_power)
            for t in range(num_rounds)
        ]
    )
    adjacency = [set() for _ in vertices]
    for i, (si, ti) in enumerate(vertices):
        set_i = set(si)
        for j in range(i + 1, len(vertices)):
            sj, tj = vertices[j]
            if ti == tj or set_i & set(sj):
                adjacency[i].add(j)
                adjacency[j].add(i)
    return SchedulingGraph(vertices, weights, adjacency)


def gwmin_mwis(graph: SchedulingGraph) -> list:
    """Algorithm 2: greedy maximum-weight independent set (GWMIN).

    Returns selected vertex indices. J(v) = v and its neighbours; beta(v) the
    degree; Q = {v : w(v) >= sum_{u in J(v)} w(u)/(beta(u)+1)};
    v* = argmax_{v in Q} w(v)/(beta(v)+1).
    """
    alive = set(range(len(graph.vertices)))
    adj = {v: set(graph.adjacency[v]) for v in alive}
    w = graph.weights
    selected = []
    while alive:
        beta = {v: len(adj[v]) for v in alive}
        q = []
        for v in alive:
            closed = adj[v] | {v}
            thresh = sum(w[u] / (beta[u] + 1) for u in closed)
            if w[v] >= thresh - 1e-12:
                q.append(v)
        if not q:  # theoretical fallback; GWMIN guarantees Q nonempty
            q = list(alive)
        v_star = max(q, key=lambda v: w[v] / (beta[v] + 1))
        selected.append(v_star)
        remove = adj[v_star] | {v_star}
        alive -= remove
        for v in alive:
            adj[v] -= remove
    return selected


def _literal_gwmin_rounds(gains_tm, weights_m, k, power_fn, noise_power):
    """Selection step of the literal Algorithm 2 (graph build + GWMIN)."""
    graph = build_scheduling_graph(gains_tm, weights_m, k, power_fn, noise_power)
    chosen = gwmin_mwis(graph)
    rounds = [()] * gains_tm.shape[0]
    for v in chosen:
        subset, t = graph.vertices[v]
        rounds[t] = subset
    return rounds


def literal_graph_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Paper-exact Algorithm 2 (explicit graph). Small M only."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    rounds = _literal_gwmin_rounds(gains_tm, weights_m, k, power_fn, noise_power)
    return finalize_schedule(
        rounds, gains_tm, weights_m, power_fn, noise_power, "literal-gwmin"
    )


# --------------------------------------------------------------------------
# Lazy (scalable) equivalent of Algorithm 2
# --------------------------------------------------------------------------

def _best_subset_for_round(
    t, avail, gains_tm, weights_m, k, power_fn, noise_power, candidate_pool, pmax
):
    """Best K-subset of `avail` for round t.

    Exact when len(avail) is small; otherwise enumerates subsets of the
    ``candidate_pool`` strongest devices (by singleton weighted rate), which
    preserves the greedy's behaviour in practice (weak devices never enter
    the argmax group). All C(pool, K) candidates are scored in a single
    batched rate-engine call; ties keep the lexicographically first subset,
    matching the seed's sequential strict-improvement loop.
    """
    avail = np.asarray(sorted(avail))
    if len(avail) > candidate_pool:
        # Stable sort so proxy ties keep the lower device id — the rule the
        # jax backend's masked ranking uses, keeping the backends identical.
        solo = _solo_proxy(gains_tm[t, avail], weights_m[avail], pmax, noise_power)
        keep = avail[np.argsort(-solo, kind="stable")[:candidate_pool]]
    else:
        keep = avail
    kk = min(k, len(keep))
    subs_vk = np.array(
        list(itertools.combinations(sorted(keep.tolist()), kk)), dtype=np.intp
    ).reshape(-1, kk)
    if len(subs_vk) == 0:
        return -np.inf, None
    vals = score_subsets(subs_vk, t, gains_tm, weights_m, power_fn, noise_power)
    i_best = int(np.argmax(vals))
    return float(vals[i_best]), tuple(subs_vk[i_best].tolist())


def _greedy_rounds_numpy(
    gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax,
    *, rounds=None, avail=None, remaining=None,
):
    """Host-path greedy selection loop (also the jax backend's tail path).

    Mutates/returns ``rounds`` (list[T] of tuples); ``avail``/``remaining``
    default to the full device/round sets so the jax driver can hand over
    mid-schedule state when fewer than K devices remain.
    """
    num_rounds, num_devices = gains_tm.shape
    if rounds is None:
        rounds = [()] * num_rounds
    if avail is None:
        avail = set(range(num_devices))
    if remaining is None:
        remaining = set(range(num_rounds))
    while remaining and len(avail) > 0:
        # max-weight vertex across all remaining rounds
        best = (-np.inf, None, None)
        for t in sorted(remaining):
            val, sub = _best_subset_for_round(
                t, avail, gains_tm, weights_m, k, search_fn, noise_power,
                candidate_pool, pmax,
            )
            if val > best[0]:
                best = (val, sub, t)
        _, subset, t = best
        if subset is None:
            break
        rounds[t] = subset
        avail -= set(subset)
        remaining.discard(t)
    return rounds


def _jax_greedy_inputs(gains_tm, weights_m, candidate_pool, k, pmax, noise_power):
    """Shared prologue of both jax drivers: clamp the pool to M, enumerate
    the C(pool, kk) subsets once as pool *positions* (lex order), and build
    the pool-ranking proxy with the *host* engine so every backend ranks
    candidate pools from identical float64 values."""
    num_devices = gains_tm.shape[1]
    pool = int(min(candidate_pool, num_devices))
    kk = min(k, pool)
    subs_pos = np.array(
        list(itertools.combinations(range(pool), kk)), dtype=np.int32
    ).reshape(-1, kk)
    solo_tm = _solo_proxy(gains_tm, weights_m[None, :], pmax, noise_power)
    return pool, kk, subs_pos, solo_tm


def _jax_greedy_tail(
    rounds, avail_np, done_np,
    gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax,
):
    """Shared epilogue of both jax drivers: once fewer than K devices remain
    (T*K > M horizons), the host loop finishes the leftover smaller groups —
    the device enumeration is fixed-K, and those tail steps are
    O(C(K-1, kk)) cheap."""
    avail_host = set(np.flatnonzero(avail_np).tolist())
    remaining_host = set(np.flatnonzero(~done_np).tolist())
    if avail_host and remaining_host:
        _greedy_rounds_numpy(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool,
            pmax, rounds=rounds, avail=avail_host, remaining=remaining_host,
        )
    return rounds


def _greedy_rounds_jax_stepwise(
    gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax
):
    """Device-path greedy selection: one jitted argmax call per step.

    Each step ``rates_jax.greedy_step`` re-masks availability and scores the
    whole (T, V, K) vertex tensor on device, but the loop itself walks on
    the host — every step syncs the argmax scalars back (the fused driver
    below removes exactly that).  Runs under x64 so scores (and therefore
    argmax tie-breaking) line up with the float64 host path.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import rates_jax

    num_rounds, num_devices = gains_tm.shape
    pool, kk, subs_pos, solo_tm = _jax_greedy_inputs(
        gains_tm, weights_m, candidate_pool, k, pmax, noise_power
    )
    rounds = [()] * num_rounds
    with jax.experimental.enable_x64():
        jg = jnp.asarray(gains_tm, jnp.float64)
        jw = jnp.asarray(weights_m, jnp.float64)
        jsolo = jnp.asarray(solo_tm, jnp.float64)
        jsubs = jnp.asarray(subs_pos)
        avail = jnp.ones(num_devices, bool)
        done = jnp.zeros(num_rounds, bool)
        avail_count = num_devices
        steps = 0
        while steps < num_rounds and avail_count >= kk:
            val, t_star, sub_ids, avail, done = rates_jax.greedy_step(
                jg, jw, jsolo, jsubs, avail, done,
                pool=pool, pmax=float(pmax), noise_power=float(noise_power),
            )
            if not bool(val > -jnp.inf):
                break
            rounds[int(t_star)] = tuple(int(d) for d in np.asarray(sub_ids))
            avail_count -= kk
            steps += 1
        avail_np = np.asarray(avail)
        done_np = np.asarray(done)
    return _jax_greedy_tail(
        rounds, avail_np, done_np,
        gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax,
    )


def _greedy_rounds_jax_fused(
    gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax,
    *, scorer="xla", shards=None,
):
    """Device-path greedy selection, fully fused: the entire GWMIN loop runs
    inside one jitted ``lax.while_loop`` (``rates_jax.greedy_rounds_fused``)
    and the host syncs exactly once per schedule, pulling the (T, K)
    assignment tensor plus the avail/done masks the T*K > M tail path
    resumes from.  ``scorer`` picks the vertex scorer (XLA comparison-matrix
    vs the Pallas SIC kernel); ``shards`` shards the subset axis over local
    devices — see the ``rates_jax`` module docstring for both switches.
    Runs under x64 so scores (and therefore argmax tie-breaking) line up
    with the float64 host path.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import rates_jax

    num_rounds, num_devices = gains_tm.shape
    pool, kk, subs_pos, solo_tm = _jax_greedy_inputs(
        gains_tm, weights_m, candidate_pool, k, pmax, noise_power
    )
    rounds = [()] * num_rounds
    with jax.experimental.enable_x64():
        assign, done, avail = rates_jax.greedy_rounds_fused(
            jnp.asarray(gains_tm, jnp.float64),
            jnp.asarray(weights_m, jnp.float64),
            jnp.asarray(solo_tm, jnp.float64),
            jnp.asarray(subs_pos),
            pool=pool, pmax=float(pmax), noise_power=float(noise_power),
            scorer=scorer, shards=shards,
        )
        # the one host sync per schedule
        assign_np, done_np, avail_np = jax.device_get((assign, done, avail))
    for t in np.flatnonzero(done_np):
        rounds[t] = tuple(int(d) for d in assign_np[t])
    return _jax_greedy_tail(
        rounds, avail_np, done_np,
        gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax,
    )


def lazy_greedy_schedule(
    gains_tm,
    weights_m,
    k,
    *,
    power_mode="max",
    pmax=0.01,
    noise_power=1e-13,
    candidate_pool=24,
    backend="numpy",
    scorer="xla",
    shards=None,
) -> Schedule:
    """Graph-free Algorithm 2 (see module docstring for the equivalence).

    ``candidate_pool`` bounds the per-round enumeration to the pool of
    strongest devices; the batched rate engine scores all C(pool, K)
    candidates in one call, so pools of 24-64 are cheap (the seed's
    per-subset loop capped practical pools at ~16).

    ``backend="jax"`` runs the whole selection loop on the device path as a
    single fused ``lax.while_loop`` (one host sync per schedule; see module
    docstring) and produces bit-identical schedules; use it for M >> 300.
    ``backend="jax-stepwise"`` keeps the one-jitted-call-per-greedy-step
    driver it replaced (still bit-identical, syncs every step).  ``scorer``
    and ``shards`` tune the fused backend only: the vertex scorer
    ("xla" | "pallas" SIC kernel) and the number of local devices the
    subset axis is sharded over (None = no shard_map).

    With power_mode="mapel" the subset *search* runs at max power and MAPEL
    refines only the selected groups — batched over all T groups in one
    ``power.mapel_batched`` call at finalization (a MAPEL solve per
    candidate subset — the literal paper procedure — is O(C(pool,K)) solves
    per round and only reorders near-ties). literal_graph_schedule keeps
    the paper's exact per-vertex power allocation."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    rounds = _lazy_gwmin_rounds(
        gains_tm, weights_m, k, pmax=pmax, noise_power=noise_power,
        candidate_pool=candidate_pool, backend=backend, scorer=scorer,
        shards=shards,
    )
    return finalize_schedule(
        rounds, gains_tm, weights_m, power_fn, noise_power, "lazy-gwmin"
    )


def _lazy_gwmin_rounds(
    gains_tm, weights_m, k, *, pmax, noise_power, candidate_pool, backend,
    scorer="xla", shards=None,
):
    """Selection step of the lazy greedy (the subset *search* runs at max
    power regardless of the finalization power mode — see
    ``lazy_greedy_schedule``)."""
    search_fn = make_power_fn("max", pmax, noise_power)
    if backend == "numpy":
        return _greedy_rounds_numpy(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax
        )
    if backend == "jax":
        return _greedy_rounds_jax_fused(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool,
            pmax, scorer=scorer, shards=shards,
        )
    if backend == "jax-stepwise":
        return _greedy_rounds_jax_stepwise(
            gains_tm, weights_m, k, search_fn, noise_power, candidate_pool, pmax
        )
    raise ValueError(
        f"unknown scheduling backend {backend!r}; known: {SCHEDULER_BACKENDS}"
    )


# --------------------------------------------------------------------------
# Exact optimum (tests only)
# --------------------------------------------------------------------------

def brute_force_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Enumerate every feasible schedule (C1/C2) — exponential, tests only."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    subsets = list(itertools.combinations(range(num_devices), k))
    subs_vk = np.array(subsets, dtype=np.intp).reshape(len(subsets), k)
    vals = {
        (s, t): v
        for t in range(num_rounds)
        for s, v in zip(
            subsets,
            score_subsets(subs_vk, t, gains_tm, weights_m, power_fn, noise_power),
        )
    }
    best_total, best_assign = -np.inf, None

    def rec(t, used, total, assign):
        nonlocal best_total, best_assign
        if t == num_rounds:
            if total > best_total:
                best_total, best_assign = total, list(assign)
            return
        for s in subsets:
            if used & set(s):
                continue
            assign.append(s)
            rec(t + 1, used | set(s), total + vals[(s, t)], assign)
            assign.pop()

    rec(0, set(), 0.0, [])
    return finalize_schedule(
        best_assign, gains_tm, weights_m, power_fn, noise_power, "brute-force"
    )


# --------------------------------------------------------------------------
# Baseline schedulers (paper §IV comparisons and ref [6] policies)
# --------------------------------------------------------------------------

def _random_rounds(rng: np.random.Generator, num_rounds, num_devices, k):
    """Selection step of random scheduling: one device permutation, chunked
    into K-groups round by round (tail rounds past the supply come back
    empty)."""
    perm = rng.permutation(num_devices)
    return [tuple(perm[t * k : (t + 1) * k].tolist()) for t in range(num_rounds)]


def random_schedule(
    rng: np.random.Generator, gains_tm, weights_m, k,
    *, power_mode="max", pmax=0.01, noise_power=1e-13,
) -> Schedule:
    """Random scheduling respecting C1 (each device at most once)."""
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    rounds = _random_rounds(rng, num_rounds, num_devices, k)
    return finalize_schedule(
        rounds, gains_tm, weights_m, power_fn, noise_power, "random"
    )


def _round_robin_rounds(num_rounds, num_devices, k):
    """Selection step of round robin: fixed device order, K per round."""
    return [
        tuple(range(min(t * k, num_devices), min((t + 1) * k, num_devices)))
        for t in range(num_rounds)
    ]


def round_robin_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13
) -> Schedule:
    """Round robin: fixed device order, K per round (ref [6] policy).

    When T*K > M the tail rounds get the leftover devices (possibly none)
    instead of emitting out-of-range device ids — C1 still holds and every
    id stays < num_devices.
    """
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    num_rounds, num_devices = gains_tm.shape
    rounds = _round_robin_rounds(num_rounds, num_devices, k)
    return finalize_schedule(
        rounds, gains_tm, weights_m, power_fn, noise_power, "round-robin"
    )


def _proportional_fair_rounds(
    gains_tm, weights_m, k, *, by_gain, pmax, noise_power
):
    """Selection step of proportional fair: greedy top-K unused devices.

    Default ranking is the weighted solo-proxy rate w_k log2(1 + p g^2 /
    sigma^2) — the same per-device quantity the MWIS objective sums — with
    a stable sort so score ties keep the lower device id.  ``by_gain=True``
    reproduces the seed's raw-gain ranking (which ignored the FedAvg
    weights the objective weighs by) bit-for-bit, unstable sort included.
    """
    num_rounds, num_devices = gains_tm.shape
    used = set()
    rounds = []
    for t in range(num_rounds):
        avail = np.array(
            [d for d in range(num_devices) if d not in used], dtype=np.intp
        )
        if by_gain:
            order = avail[np.argsort(-gains_tm[t, avail])]
        else:
            score = _solo_proxy(
                gains_tm[t, avail], weights_m[avail], pmax, noise_power
            )
            order = avail[np.argsort(-score, kind="stable")]
        grp = tuple(order[:k].tolist())
        used |= set(grp)
        rounds.append(grp)
    return rounds


def proportional_fair_schedule(
    gains_tm, weights_m, k, *, power_mode="max", pmax=0.01, noise_power=1e-13,
    by_gain=False,
) -> Schedule:
    """Per round, pick the K best unused devices by weighted solo rate.

    The ranking is w_k R_k^solo (see ``_proportional_fair_rounds``) so this
    baseline competes on the objective the MWIS scheduler is scored against;
    the seed ranked by raw channel gain, which starves high-weight /
    mid-gain devices — pass ``by_gain=True`` to reproduce that behaviour.

    When every device has been used before the horizon ends (T*K > M) the
    remaining rounds get empty groups, like round-robin's tail — the intp
    dtype keeps the empty-``avail`` gather legal (a bare ``np.array([])`` is
    float64 and rejects fancy indexing).
    """
    power_fn = make_power_fn(power_mode, pmax, noise_power)
    rounds = _proportional_fair_rounds(
        gains_tm, weights_m, k, by_gain=by_gain, pmax=pmax,
        noise_power=noise_power,
    )
    return finalize_schedule(
        rounds, gains_tm, weights_m, power_fn, noise_power, "proportional-fair"
    )


# --------------------------------------------------------------------------
# SchedulerPolicy protocol, registry, and the registered policies
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Everything a policy may read at ``init_state`` time.

    The FL runtime builds this from ``FLConfig`` + the cell physics
    (``fl.policy_config``); standalone callers construct it directly.
    ``seed`` seeds any policy-internal randomness — schedules must be
    reproducible from (inputs, PolicyConfig) alone.
    """

    group_size: int                 # K
    power_mode: str = "max"         # finalization allocator (max | mapel)
    pmax: float = 0.01
    noise_power: float = 1e-13
    candidate_pool: int = 24        # lazy greedy enumeration bound
    backend: str = "numpy"          # lazy greedy driver (SCHEDULER_BACKENDS)
    scorer: str = "xla"             # fused-backend vertex scorer (xla | pallas)
    shards: "int | None" = None     # fused-backend vertex-axis device shards
    ota_noise: float = 0.0          # OTA receiver noise std (matching-pursuit
                                    # aggregation-error model; 0 = noiseless)
    seed: int = 0


@dataclasses.dataclass
class Observation:
    """Online observables fed to ``select_round`` (all (M,) arrays).

    The FL runtime updates these after every live round
    (:meth:`record_round`); offline drivers (:func:`build_schedule`) feed
    realized rates and participation but no update norms (there is no FL
    state outside the training loop).
    """

    update_norms: np.ndarray    # last observed ||delta W_k||_2; 0 if never
    participation: np.ndarray   # rounds device k was scheduled so far
    last_round: np.ndarray      # last round k participated; -1 if never
    realized_rates: np.ndarray  # rate k achieved when last scheduled; 0 if never

    @classmethod
    def initial(cls, num_devices: int) -> "Observation":
        return cls(
            update_norms=np.zeros(num_devices),
            participation=np.zeros(num_devices, dtype=np.intp),
            last_round=np.full(num_devices, -1, dtype=np.intp),
            realized_rates=np.zeros(num_devices),
        )

    def record_round(self, t, group, rates_k, update_norms_k=None) -> "Observation":
        """Functional update after round t (the caller keeps the new copy,
        so a policy holding an old Observation never sees the future)."""
        obs = Observation(
            self.update_norms.copy(), self.participation.copy(),
            self.last_round.copy(), self.realized_rates.copy(),
        )
        idx = np.asarray(group, dtype=np.intp)
        if idx.size:
            obs.participation[idx] += 1
            obs.last_round[idx] = t
            obs.realized_rates[idx] = np.asarray(rates_k, dtype=np.float64)
            if update_norms_k is not None:
                obs.update_norms[idx] = np.asarray(update_norms_k, dtype=np.float64)
        return obs


class TracedObservation(NamedTuple):
    """The jnp mirror of :class:`Observation`, threaded through the
    scanned-horizon ``lax.scan`` carry (``fl_engine._online_horizon_core``).

    A NamedTuple of arrays, so it is a pytree the scan can carry.
    ``realized_rates`` is omitted on purpose: no registered traced policy
    reads it (the scores consume the *solo* rate proxy, not the realized
    SIC rate), and dropping it keeps the carry minimal — add it here (and
    in the engine's scatter update) if a future policy needs it.
    """

    update_norms: Any    # (M,) f32 last observed ||delta W_k||; the carry
                         # seeds these at the policy's COLD_START_NORM
    participation: Any   # (M,) i32 rounds device k was scheduled so far
    last_round: Any      # (M,) i32 last round k participated; -1 if never

    @classmethod
    def initial(cls, num_devices: int,
                cold_start_norm: float = 1.0) -> "TracedObservation":
        import jax.numpy as jnp

        return cls(
            update_norms=jnp.full(num_devices, cold_start_norm, jnp.float32),
            participation=jnp.zeros(num_devices, jnp.int32),
            last_round=jnp.full(num_devices, -1, jnp.int32),
        )


def _norm_estimates_traced(obs: "TracedObservation", cold_start: float):
    """jnp mirror of the shared numpy norm-estimate convention
    (``UpdateAwarePolicy._score`` / ``MatchingPursuitPolicy._norm_estimates``):
    devices never yet observed take the running mean of observed norms
    (``cold_start`` before any observation) and observed-zero norms are
    floored at 1e-3 of the default, so no device is starved forever."""
    import jax.numpy as jnp

    seen = obs.participation > 0
    cnt = jnp.sum(seen.astype(jnp.float32))
    total = jnp.sum(jnp.where(seen, obs.update_norms, 0.0))
    default = jnp.where(
        cnt > 0.0, total / jnp.maximum(cnt, 1.0), jnp.float32(cold_start)
    )
    default = jnp.maximum(default, 1e-12)
    return jnp.where(
        seen, jnp.maximum(obs.update_norms, 1e-3 * default), default
    )


class SchedulerPolicy(Protocol):
    """The scheduling policy protocol (see module docstring).

    ``online`` declares whether ``select_round`` reads FL state from the
    Observation (live mode inside the training loop) or replays a
    precomputed plan; ``respects_c1`` whether the policy schedules each
    device at most once over the horizon (the paper's C1).  Online
    policies may additionally declare ``needs_norms`` (default True) —
    set it False to tell the FL loop not to compute per-device update
    norms the policy never reads.

    Online policies opting into the scanned horizon implement the traced
    protocol on top (``traced_protocol = True``):

        aux = policy.init_traced(gains_tm, weights_m, cfg)   # host, once
        dev_k, mask_k = policy.select_round_traced(
            t, solo_m, gains_m, weights_m, obs, cfg)         # traced

    ``init_traced`` returns host numpy float32 aux tensors (currently the
    (T, M) weighted solo-rate table, computed in float64 and cast once);
    ``select_round_traced`` receives that table's round-t row plus the
    round's jnp channel row and a :class:`TracedObservation`, and returns
    a fixed-shape (K,) int32 device vector with a (K,) bool validity mask
    (lanes masked False are padding — the engine drops their scatter
    updates and zeroes their aggregation weights).
    """

    name: str
    online: bool
    respects_c1: bool

    def init_state(self, gains_tm: np.ndarray, weights_m: np.ndarray,
                   cfg: PolicyConfig) -> Any: ...

    def select_round(self, t: int, state: Any,
                     obs: Observation) -> "tuple[tuple, Any]": ...


_REGISTRY: "dict[str, type]" = {}


def register_policy(name: str):
    """Class decorator registering a SchedulerPolicy under ``name``.

    The name immediately becomes a valid ``FLConfig.scheduler`` value
    (config validation reads :func:`available_policies`).
    """

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_policy(name: str, **options) -> "SchedulerPolicy":
    """Instantiate the policy registered under ``name``.

    ``options`` are forwarded to the policy constructor (e.g.
    ``get_policy("proportional-fair", by_gain=True)``).
    """
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; registered: {available_policies()}"
        ) from None
    return cls(**options)


def available_policies() -> tuple:
    """Sorted names of all registered policies."""
    return tuple(sorted(_REGISTRY))


def policy_is_online(name: str) -> bool:
    """Whether the policy registered under ``name`` selects from live FL
    state (``online = True``).

    Half of the horizon-mode gate: online policies need FL-state feedback
    every round, so under ``FLConfig.horizon = "scan"`` they must carry
    that feedback *inside* the device program via the traced protocol
    (:func:`policy_is_traced`) — config validation and the scanned driver
    ask these two questions together.  Raises ValueError for unregistered
    names (same as :func:`get_policy`).
    """
    return bool(getattr(get_policy(name), "online", False))


def policy_is_traced(name: str) -> bool:
    """Whether the policy registered under ``name`` implements the traced
    selection protocol (``traced_protocol = True`` + ``init_traced`` /
    ``select_round_traced`` — see :class:`SchedulerPolicy`).

    The other half of the horizon-mode gate: an *online* policy runs under
    ``FLConfig.horizon = "scan"`` iff this is True (its selection loop
    then executes inside ``fl_engine._online_horizon_core``'s scan body).
    Raises ValueError for unregistered names (same as :func:`get_policy`).
    """
    return bool(getattr(get_policy(name), "traced_protocol", False))


def build_schedule(
    policy: "SchedulerPolicy", gains_tm, weights_m, cfg: PolicyConfig
) -> Schedule:
    """Drive any policy over the whole horizon and finalize the result.

    Precomputed policies run their one-shot plan in ``init_state`` and this
    reduces to plan + shared finalization — bit-identical to the historical
    per-scheduler functions.  Online policies are driven with realized
    rates and participation fed back between rounds, but no update norms
    (FL state exists only inside ``fl.run_federated_learning``'s live
    mode); useful for rate-only studies and benchmarks.
    """
    gains_tm = np.asarray(gains_tm)
    weights_m = np.asarray(weights_m)
    num_rounds, num_devices = gains_tm.shape
    power_fn = power_lib.make_power_allocator(
        cfg.power_mode, cfg.pmax, cfg.noise_power
    )
    state = policy.init_state(gains_tm, weights_m, cfg)
    obs = Observation.initial(num_devices)
    online = getattr(policy, "online", False)
    rounds, powers, rates, total = [], [], [], 0.0
    for t in range(num_rounds):
        group, state = policy.select_round(t, state, obs)
        group = tuple(int(d) for d in group)
        rounds.append(group)
        if online:
            # the loop must allocate per round anyway (the policy reads the
            # realized rates next round), so keep the results instead of
            # re-solving every group in a trailing finalize_schedule pass
            p_k, r_k = finalize_round(
                group, t, gains_tm, weights_m, power_fn, cfg.noise_power
            )
            obs = obs.record_round(t, group, r_k)
            powers.append(p_k)
            rates.append(r_k)
            total += float(np.sum(weights_m[np.asarray(group, np.intp)] * r_k))
    revisits = not getattr(policy, "respects_c1", True)
    if online:
        sched = Schedule(rounds, powers, rates, total, policy.name, revisits)
    else:
        sched = finalize_schedule(
            rounds, gains_tm, weights_m, power_fn, cfg.noise_power, policy.name
        )
        sched.allow_revisits = revisits
    sched.validate(num_devices, cfg.group_size)
    return sched


class _PrecomputedPolicy:
    """Base for offline policies: plan the whole horizon in ``init_state``
    (selection depends only on channel realizations), replay per round."""

    online = False
    respects_c1 = True

    def init_state(self, gains_tm, weights_m, cfg: PolicyConfig):
        return self._plan(np.asarray(gains_tm), np.asarray(weights_m), cfg)

    def select_round(self, t, state, obs):
        return tuple(state[t]), state


@register_policy("lazy-gwmin")
class LazyGwminPolicy(_PrecomputedPolicy):
    """Graph-free Algorithm 2 (the paper's proposed MWIS scheduler)."""

    def _plan(self, gains_tm, weights_m, cfg):
        return _lazy_gwmin_rounds(
            gains_tm, weights_m, cfg.group_size, pmax=cfg.pmax,
            noise_power=cfg.noise_power, candidate_pool=cfg.candidate_pool,
            backend=cfg.backend, scorer=cfg.scorer, shards=cfg.shards,
        )


@register_policy("literal-gwmin")
class LiteralGwminPolicy(_PrecomputedPolicy):
    """Algorithm 2 on the explicit scheduling graph (small M only)."""

    def _plan(self, gains_tm, weights_m, cfg):
        power_fn = power_lib.make_power_allocator(
            cfg.power_mode, cfg.pmax, cfg.noise_power
        )
        return _literal_gwmin_rounds(
            gains_tm, weights_m, cfg.group_size, power_fn, cfg.noise_power
        )


@register_policy("random")
class RandomPolicy(_PrecomputedPolicy):
    """Random C1-respecting schedule, reproducible from the policy alone.

    The RNG is derived in ``init_state`` from ``cfg.seed + SEED_OFFSET``;
    the offset decorrelates the scheduling permutation from the model-init /
    channel streams that consume ``cfg.seed`` directly.  (Historically the
    ``+ 17`` lived as a magic number inside ``fl.make_schedule``.)
    """

    SEED_OFFSET = 17

    def _plan(self, gains_tm, weights_m, cfg):
        rng = np.random.default_rng(cfg.seed + self.SEED_OFFSET)
        num_rounds, num_devices = gains_tm.shape
        return _random_rounds(rng, num_rounds, num_devices, cfg.group_size)


@register_policy("round-robin")
class RoundRobinPolicy(_PrecomputedPolicy):
    """Fixed device order, K per round (ref [6] baseline)."""

    def _plan(self, gains_tm, weights_m, cfg):
        num_rounds, num_devices = gains_tm.shape
        return _round_robin_rounds(num_rounds, num_devices, cfg.group_size)


@register_policy("proportional-fair")
class ProportionalFairPolicy(_PrecomputedPolicy):
    """Greedy top-K unused devices by weighted solo rate (``by_gain=True``
    reproduces the seed's raw-gain ranking)."""

    def __init__(self, by_gain: bool = False):
        self.by_gain = by_gain

    def _plan(self, gains_tm, weights_m, cfg):
        return _proportional_fair_rounds(
            gains_tm, weights_m, cfg.group_size, by_gain=self.by_gain,
            pmax=cfg.pmax, noise_power=cfg.noise_power,
        )


class _ScoreTopKPolicy:
    """Base for online policies: rank all devices by a per-round score and
    take the top K (stable sort, ties to the lower device id).  Subclasses
    implement ``_score(t, solo, obs) -> (M,)`` where ``solo`` is the
    weighted interference-free rate w_k log2(1 + p g_k^2 / sigma^2) at
    round t.  Online policies revisit devices across rounds — long-horizon
    fairness is the score's job, not C1's.
    """

    online = True
    respects_c1 = False
    needs_norms = False     # True: the FL loop computes ||delta W_k|| per
                            # scheduled device and feeds it back via obs
    traced_protocol = True  # subclasses supply _score_traced, the jnp
                            # mirror of _score (same ranking, f32)

    def init_state(self, gains_tm, weights_m, cfg: PolicyConfig):
        return {
            "gains": np.asarray(gains_tm),
            "weights": np.asarray(weights_m),
            "cfg": cfg,
        }

    def select_round(self, t, state, obs):
        cfg = state["cfg"]
        solo = _solo_proxy(
            state["gains"][t], state["weights"], cfg.pmax, cfg.noise_power
        )
        score = np.asarray(self._score(t, solo, obs), dtype=np.float64)
        k = min(cfg.group_size, len(score))
        top = np.argsort(-score, kind="stable")[:k]
        return tuple(int(d) for d in top), state

    def init_traced(self, gains_tm, weights_m, cfg: PolicyConfig) -> dict:
        """Host aux for the traced path: the (T, M) weighted solo-rate
        table, computed in float64 (exactly what ``select_round`` sees)
        and cast once to the program's float32."""
        solo = _solo_proxy(
            np.asarray(gains_tm, np.float64),
            np.asarray(weights_m, np.float64),
            cfg.pmax, cfg.noise_power,
        )
        return {"solo": np.asarray(solo, np.float32)}

    def select_round_traced(self, t, solo_m, gains_m, weights_m, obs, cfg):
        """jnp mirror of ``select_round``: top-K of ``_score_traced`` via
        ``lax.top_k`` (ties to the lower device id, matching the stable
        descending argsort).  Top-K policies always fill all K lanes, so
        the validity mask is all-True."""
        import jax
        import jax.numpy as jnp

        score = self._score_traced(t, solo_m, obs)
        k = min(int(cfg.group_size), int(score.shape[0]))
        _, top = jax.lax.top_k(score, k)
        return top.astype(jnp.int32), jnp.ones(k, dtype=bool)


@register_policy("update-aware")
class UpdateAwarePolicy(_ScoreTopKPolicy):
    """Update-aware scheduling (Amiri et al., arXiv:2001.10402).

    Score = (estimated ||delta W_k||_2) * (weighted solo rate): devices
    whose recent local updates were large *and* whose uplink is currently
    fast win the slot — the BN2-BC flavour of the reference, with the last
    observed norm standing in for the (untransmitted) current one.  Devices
    never yet observed take the running mean of observed norms (1.0 before
    any observation), so round 0 reduces to best-channel and unexplored
    devices stay competitive; observed-zero norms are floored so a device
    whose local gradient once came back numerically zero (the norm is
    taken on the raw pre-quantization delta) is merely deprioritized, not
    starved forever.
    """

    needs_norms = True
    COLD_START_NORM = 1.0   # the documented cold-start estimate: stands in
                            # for ||delta W_k|| before any observation, so
                            # round 0 reduces to best-channel; the traced
                            # carry seeds its norms with it too

    def _score(self, t, solo, obs):
        norms = obs.update_norms.copy()
        seen = obs.participation > 0
        default = (
            float(norms[seen].mean()) if seen.any() else self.COLD_START_NORM
        )
        default = max(default, 1e-12)
        norms[~seen] = default
        norms[seen] = np.maximum(norms[seen], 1e-3 * default)
        return norms * solo

    def _score_traced(self, t, solo_m, obs):
        return _norm_estimates_traced(obs, self.COLD_START_NORM) * solo_m


@register_policy("age-fair")
class AgeFairPolicy(_ScoreTopKPolicy):
    """Age-fair scheduling (Yang et al., arXiv:1908.06287).

    Score = (1 + age_k) * (weighted solo rate), age_k = rounds since device
    k last participated (never-scheduled devices age from round 0).  The
    staleness boost grows without bound, so every device is eventually
    rescheduled no matter how weak its channel — the update-age fairness
    the reference shows FL needs over long horizons.
    """

    def _score(self, t, solo, obs):
        age = (t - obs.last_round).astype(np.float64)
        return (1.0 + age) * solo

    def _score_traced(self, t, solo_m, obs):
        import jax.numpy as jnp

        age = (t - obs.last_round).astype(jnp.float32)
        return (1.0 + age) * solo_m


@register_policy("matching-pursuit")
class MatchingPursuitPolicy:
    """Greedy residual-error device selection for over-the-air aggregation.

    The analog PS estimate (core/ota.py) misses the updates of unscheduled
    devices and pays receiver noise amplified by the weakest admitted
    channel (truncated inversion: eta <= pmax h_k^2 / (w_k n_k)^2 for every
    admitted k).  Modeling the round's aggregation error of a candidate set
    S as

        E(S) = sum_{k not in S} (w_k n_k)^2
             + lambda * max_{k in S} (w_k n_k / h_k)^2,
        lambda = ota_noise^2 / pmax,

    the policy runs a matching-pursuit sweep: start from S = {} (error =
    total update energy), repeatedly admit the device giving the largest
    *strict* decrease of E, and stop at K devices or when no admission
    helps — a weak-channel device whose noise penalty outweighs its energy
    contribution is left out even when slots remain.  With ``ota_noise = 0``
    the noise term vanishes and the sweep reduces to top-K by w_k n_k.

    Norm estimates follow ``update-aware``'s convention: devices never yet
    observed take the running mean of observed norms (1.0 before any
    observation) and observed-zero norms are floored, so round 0 is a pure
    channel/weight ranking and no device is starved forever.
    """

    online = True
    respects_c1 = False
    needs_norms = True
    traced_protocol = True
    COLD_START_NORM = 1.0   # shared with update-aware: the documented
                            # stand-in norm before any observation

    def init_state(self, gains_tm, weights_m, cfg: PolicyConfig):
        return {
            "gains": np.asarray(gains_tm),
            "weights": np.asarray(weights_m),
            "cfg": cfg,
        }

    @classmethod
    def _norm_estimates(cls, obs: Observation) -> np.ndarray:
        norms = obs.update_norms.copy()
        seen = obs.participation > 0
        default = (
            float(norms[seen].mean()) if seen.any() else cls.COLD_START_NORM
        )
        default = max(default, 1e-12)
        norms[~seen] = default
        norms[seen] = np.maximum(norms[seen], 1e-3 * default)
        return norms

    def select_round(self, t, state, obs):
        cfg = state["cfg"]
        gains = np.asarray(state["gains"][t], dtype=np.float64)
        weights = np.asarray(state["weights"], dtype=np.float64)
        m = weights * self._norm_estimates(obs)        # w_k n_k
        energy = m * m                                 # omission cost
        lam = float(cfg.ota_noise) ** 2 / max(float(cfg.pmax), 1e-300)
        if lam > 0.0:
            with np.errstate(divide="ignore"):
                pen = lam * np.where(gains > 0.0, (m / gains) ** 2, np.inf)
        else:
            pen = np.zeros_like(m)     # explicit: avoids 0 * inf = nan
        k = min(cfg.group_size, len(m))
        selected: "list[int]" = []
        in_s = np.zeros(len(m), dtype=bool)
        residual = float(energy.sum())     # sum over k not in S
        noise_term = 0.0                   # lambda * max admitted penalty
        cur = residual + noise_term
        for _ in range(k):
            cand_noise = np.maximum(noise_term, pen)
            e = (residual - energy) + cand_noise
            e[in_s] = np.inf
            j = int(np.argmin(e))
            if not e[j] < cur:     # admit only on strict decrease
                break
            selected.append(j)
            in_s[j] = True
            residual -= float(energy[j])
            noise_term = max(noise_term, float(pen[j]))
            cur = float(e[j])
        return tuple(selected), state

    def init_traced(self, gains_tm, weights_m, cfg: PolicyConfig) -> dict:
        """Same aux contract as the top-K policies (the engine feeds every
        traced policy the solo table); the admit loop itself only reads
        the channel row, the weights and the norm estimates."""
        solo = _solo_proxy(
            np.asarray(gains_tm, np.float64),
            np.asarray(weights_m, np.float64),
            cfg.pmax, cfg.noise_power,
        )
        return {"solo": np.asarray(solo, np.float32)}

    def select_round_traced(self, t, solo_m, gains_m, weights_m, obs, cfg):
        """The matching-pursuit sweep as a ``lax.while_loop``: one admit
        per iteration, stopping at K admissions or the first candidate
        that fails the strict-decrease test — the same early exit as the
        numpy loop, so both paths admit identical devices in identical
        order.  Lanes past the stop count are padding (mask False)."""
        import jax
        import jax.numpy as jnp

        m_arr = weights_m * _norm_estimates_traced(obs, self.COLD_START_NORM)
        energy = m_arr * m_arr
        lam = float(cfg.ota_noise) ** 2 / max(float(cfg.pmax), 1e-300)
        if lam > 0.0:
            safe_g = jnp.where(gains_m > 0.0, gains_m, 1.0)
            pen = jnp.where(
                gains_m > 0.0, lam * (m_arr / safe_g) ** 2, jnp.inf
            )
        else:
            pen = jnp.zeros_like(m_arr)   # explicit: avoids 0 * inf = nan
        k = min(int(cfg.group_size), int(m_arr.shape[0]))
        inf = jnp.asarray(jnp.inf, m_arr.dtype)

        def cond(c):
            cnt, _, _, _, _, _, stop = c
            return jnp.logical_and(cnt < k, jnp.logical_not(stop))

        def step(c):
            cnt, in_s, residual, noise_term, cur, sel, _ = c
            cand_noise = jnp.maximum(noise_term, pen)
            e = jnp.where(in_s, inf, (residual - energy) + cand_noise)
            j = jnp.argmin(e)              # first occurrence, like numpy
            admit = e[j] < cur             # strict decrease only
            sel = sel.at[cnt].set(jnp.where(admit, j.astype(jnp.int32), 0))
            in_s = in_s.at[j].set(jnp.logical_or(in_s[j], admit))
            return (
                cnt + jnp.where(admit, 1, 0).astype(jnp.int32),
                in_s,
                jnp.where(admit, residual - energy[j], residual),
                jnp.where(admit, jnp.maximum(noise_term, pen[j]), noise_term),
                jnp.where(admit, e[j], cur),
                sel,
                jnp.logical_not(admit),
            )

        total = jnp.sum(energy)
        c0 = (
            jnp.zeros((), jnp.int32),
            jnp.zeros(m_arr.shape[0], dtype=bool),
            total,
            jnp.zeros((), m_arr.dtype),
            total,
            jnp.zeros(k, jnp.int32),
            jnp.asarray(False),
        )
        cnt, _, _, _, _, sel, _ = jax.lax.while_loop(cond, step, c0)
        return sel, jnp.arange(k, dtype=jnp.int32) < cnt
