"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke] [--only NAME]
                                            [--check-regression]

``--smoke`` is the CI mode: implies ``--fast`` and skips the FL-training
suites (fig5/fig6) plus the roofline sweep, so the job finishes in minutes
while still exercising the power, scheduling, kernel, and compression paths.

``--check-regression`` gates the persisted suites: after the fresh records
are written, each timing metric is compared against the committed baseline
JSON (same filename, snapshotted before the run overwrites it) and the run
fails if the *median* fresh/baseline ratio over all matched records exceeds
1.20 — a single noisy record doesn't trip it, a broad slowdown does.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
The scheduling, fl_engine and fl_cells suites additionally return sweep
records that are persisted at the repo root (``BENCH_scheduling.json``: M
sweep x numpy/jax scheduler backend; ``BENCH_fl.json``: K x M round-loop
sweep, legacy vs batched FL engine; ``BENCH_cells.json``: cells x seeds x M
sweep, scanned grid vs sequential per-round dispatch;
``BENCH_policy.json``: online-policy horizons, traced scan vs per-round
host loop; ``BENCH_payload.json``: transformer-class payload-size sweep,
chunked Pallas aggregation vs XLA einsum) so the perf trajectories are
tracked from PR to PR.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import traceback

# "module" or "module:function" (default function: main)
SUITES = [
    ("power", "benchmarks.power_bench"),           # §III-C / ref [8]
    ("scheduling", "benchmarks.scheduling_bench"), # §III-A/B Algorithm 2
    ("kernels", "benchmarks.kernel_bench"),        # §II-B codec hot-spot
    ("compression", "benchmarks.compression_stats"),  # §II-B adaptive bits
    ("fl_engine", "benchmarks.fl_bench"),          # legacy vs batched round loop
    ("fl_cells", "benchmarks.fl_bench:cells_main"),  # scanned cells x seeds sweep
    ("policy", "benchmarks.policy_bench"),         # online-policy traced scan
    ("payload", "benchmarks.payload_bench"),       # LLM-scale aggregation
    ("ota", "benchmarks.ota_bench"),               # analog vs digital uplink
    ("fig5", "benchmarks.fig5_noma_vs_tdma"),      # Fig. 5
    ("fig6", "benchmarks.fig6_schemes"),           # Fig. 6
    ("roofline", "benchmarks.roofline_bench"),     # EXPERIMENTS §Roofline
]

# FL-training suites (minutes even at --fast) and the roofline sweep are out
# of scope for the CI smoke job.  fl_engine/fl_cells stay in: their --fast
# cases are one tiny cell each and they are the smoke signals for the
# batched round engine and the scanned sweep driver regressing.
SMOKE_SKIP = {"fig5", "fig6", "roofline"}

# Suites whose main() returns a dict of records persisted at the repo root
# (suffixed _fast under --fast/--smoke so the tracked full-sweep record is
# never clobbered by a small run).
PERSIST = {
    "scheduling": "BENCH_scheduling",
    "fl_engine": "BENCH_fl",
    "fl_cells": "BENCH_cells",
    "policy": "BENCH_policy",
    "payload": "BENCH_payload",
    "ota": "BENCH_ota",
}

# --check-regression: per-suite wall-time metrics (everything else in a
# record is part of its identity key).  Derived columns like "speedup" are
# deliberately absent — they are ratios of these, and gating them twice
# would double-count noise.
REGRESSION_METRICS = {
    "scheduling": ("seconds",),
    "fl_engine": ("legacy_s_per_round", "batched_s_per_round"),
    "fl_cells": ("scan_sweep_s", "per_round_legacy_sweep_s",
                 "per_round_batched_sweep_s"),
    "policy": ("scan_horizon_s", "per_round_legacy_horizon_s",
               "per_round_batched_horizon_s"),
    "payload": ("einsum_s", "pallas_chunked_s"),
    "ota": ("horizon_s",),
}
REGRESSION_THRESHOLD = 1.20


def _record_key(record: dict, metrics: tuple):
    return tuple(sorted(
        (k, v) for k, v in record.items()
        if k not in metrics and not k.startswith("speedup")
    ))


def check_regression(name: str, fresh: dict, baseline: dict) -> list:
    """Median fresh/baseline ratio per metric; returns failure strings."""
    metrics = REGRESSION_METRICS[name]
    base_by_key = {
        _record_key(r, metrics): r for r in baseline.get("records", [])
    }
    failures = []
    for metric in metrics:
        ratios = []
        for rec in fresh.get("records", []):
            base = base_by_key.get(_record_key(rec, metrics))
            if base is None or metric not in base or metric not in rec:
                continue
            if base[metric] > 0:
                ratios.append(rec[metric] / base[metric])
        if not ratios:
            print(f"# regression-check {name}.{metric}: no matching "
                  f"baseline records, skipped", flush=True)
            continue
        med = statistics.median(ratios)
        status = "OK" if med <= REGRESSION_THRESHOLD else "REGRESSED"
        print(f"# regression-check {name}.{metric}: median ratio "
              f"{med:.3f} over {len(ratios)} records ({status})", flush=True)
        if med > REGRESSION_THRESHOLD:
            failures.append(f"{name}.{metric} median ratio {med:.3f} > "
                            f"{REGRESSION_THRESHOLD}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: --fast minus the FL-training suites")
    ap.add_argument("--only", default=None)
    ap.add_argument("--check-regression", action="store_true",
                    help="fail if a persisted suite's fresh timings are "
                         ">20%% (median) over the committed baseline JSON")
    args = ap.parse_args()
    fast = args.fast or args.smoke
    suffix = "_fast" if fast else ""
    root = pathlib.Path(__file__).resolve().parent.parent

    # Snapshot the committed baselines BEFORE the suites overwrite them.
    baselines = {}
    if args.check_regression:
        for name, stem in PERSIST.items():
            path = root / f"{stem}{suffix}.json"
            if path.exists():
                baselines[name] = json.loads(path.read_text())

    import importlib

    failures = []
    regressions = []
    for name, target in SUITES:
        if args.only and args.only != name:
            continue
        if args.smoke and name in SMOKE_SKIP and args.only != name:
            continue
        module, _, func = target.partition(":")
        print(f"# === {name} ({target}) ===", flush=True)
        try:
            entry = getattr(importlib.import_module(module), func or "main")
            result = entry(fast=fast)
            if name in PERSIST and isinstance(result, dict):
                out = root / f"{PERSIST[name]}{suffix}.json"
                out.write_text(json.dumps(result, indent=2) + "\n")
                print(f"# wrote {out}", flush=True)
                if args.check_regression:
                    if name in baselines:
                        regressions += check_regression(
                            name, result, baselines[name])
                    else:
                        print(f"# regression-check {name}: no committed "
                              f"baseline, skipped", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED suites: {failures}")
        sys.exit(1)
    if regressions:
        print(f"# PERF REGRESSIONS: {regressions}")
        sys.exit(1)
    print("# all suites ok")


if __name__ == "__main__":
    main()
