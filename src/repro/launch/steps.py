"""Step functions (train / prefill / serve) and their input specs.

The FL-NOMA integration at LLM scale (DESIGN.md §2): ``make_train_step``
inserts the paper's DoReFa quantize->dequantize on the gradient pytree
between backward and optimizer — the "uplink" of Algorithm 1 — with the
bit-width ``fl_bits`` supplied per round by the NOMA rate model.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.core import compression
from repro.models import Model


# --------------------------------------------------------------------------
# Abstract inputs (dry-run stand-ins; no allocation)
# --------------------------------------------------------------------------

def enc_frames(shape: ShapeConfig) -> int:
    """Stub audio frontend length: 4 tokens per frame."""
    return max(shape.seq_len // 4, 64)


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            batch["img_feats"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "encdec":
            batch["enc_feats"] = jax.ShapeDtypeStruct(
                (b, enc_frames(shape), cfg.d_model), jnp.bfloat16
            )
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["img_feats"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "encdec":
            batch["enc_feats"] = jax.ShapeDtypeStruct(
                (b, enc_frames(shape), cfg.d_model), jnp.bfloat16
            )
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "vlm":
        batch["img_feats"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["enc_out"] = jax.ShapeDtypeStruct(
            (b, enc_frames(shape), cfg.d_model), jnp.bfloat16
        )
    return batch


def abstract_cache(model: Model, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )


# --------------------------------------------------------------------------
# Steps
# --------------------------------------------------------------------------

def make_train_step(model: Model, optimizer, *, fl_bits: Optional[int] = None,
                    unroll: bool = False, kv_chunk: int = 1024,
                    grad_accum: int = 1, remat: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, loss).

    grad_accum > 1 splits the global batch into interleaved microbatches
    (each microbatch stays sharded across the full data axis) and scans
    them, accumulating fp32 gradients. This bounds live remat activations to
    one microbatch — the standard fix for deep-model train memory
    (EXPERIMENTS.md §Perf). The paper's quantization applies to the
    *accumulated* round gradient, matching Algorithm 1 (one uplink/round).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss)(
            params, batch, unroll=unroll, kv_chunk=kv_chunk, remat=remat
        )

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            def split(x):
                b = x.shape[0]
                mb = b // grad_accum
                # interleave so each microbatch spans every data shard
                return x.reshape(mb, grad_accum, *x.shape[1:]).swapaxes(0, 1)

            micro = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                gsum, lsum = carry
                loss, g = grads_of(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
        else:
            loss, grads = grads_of(params, batch)
        if fl_bits is not None and fl_bits < 32:
            grads = compression.encode_decode_tree(grads, fl_bits)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        return new_params, new_state, loss

    return train_step


def make_prefill_step(model: Model, shape: ShapeConfig, *, unroll: bool = False,
                      kv_chunk: int = 1024):
    """(params, batch) -> (last_logits, caches). Caches built inside."""

    def prefill_step(params, batch):
        caches = model.init_cache(shape.global_batch, shape.seq_len)
        kw = {}
        if model.cfg.family == "vlm":
            kw["img_feats"] = batch["img_feats"]
        if model.cfg.family == "encdec":
            kw["enc_feats"] = batch["enc_feats"]
        out = model.module.forward(
            params, batch["tokens"], model.cfg, caches=caches,
            remat=False, unroll=unroll, kv_chunk=kv_chunk, **kw
        )
        logits, caches = out[0], out[1]
        return logits[:, -1:], caches

    return prefill_step


def make_serve_step(model: Model, *, unroll: bool = False, kv_chunk: int = 4096):
    """(params, caches, batch) -> (next_token, caches). Greedy decode."""

    def serve_step(params, caches, batch):
        logits, new_caches = model.decode_step(
            params, caches, batch["tokens"], batch=batch,
            kv_chunk=kv_chunk, unroll=unroll,
        )
        nxt = jnp.argmax(logits[:, -1, : model.cfg.vocab_size], axis=-1)
        return nxt.astype(jnp.int32)[:, None], new_caches

    return serve_step
