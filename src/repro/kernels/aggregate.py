"""Pallas kernel for the PS-side fused dequant + weighted aggregation.

Server aggregation (paper Algorithm 1 line 10): theta update is the weighted
sum of K dequantized client payloads. Fusing dequant+scale+sum keeps each
code tile in VMEM exactly once instead of K separate dequant passes +
K-way add in HBM.

Tiling: codes are (K, R, 128); each grid step loads a (K, BLOCK_ROWS, 128)
brick (K <= 8 in practice, so the brick stays well under VMEM limits) and
reduces over K in registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dorefa import BLOCK_ROWS, LANE


def _aggregate_kernel(c_ref, sw_ref, o_ref, *, a: float, k: int):
    # c_ref: (K, BLOCK_ROWS, LANE) int32; sw_ref: (K, 2) [scale, weight]
    acc = jnp.zeros((c_ref.shape[1], c_ref.shape[2]), jnp.float32)
    for i in range(k):  # K is small and static: unrolled VPU adds
        coeff = sw_ref[i, 0] * sw_ref[i, 1] / a
        acc = acc + c_ref[i, :, :].astype(jnp.float32) * coeff
    o_ref[...] = acc


def weighted_aggregate_pallas(
    codes: jax.Array,     # (K, R, LANE) int32
    scales: jax.Array,    # (K,)
    weights: jax.Array,   # (K,)
    bits: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    k, rows, lane = codes.shape
    assert lane == LANE and rows % BLOCK_ROWS == 0
    a = float(2 ** int(bits) - 1)
    sw = jnp.stack([scales.astype(jnp.float32), weights.astype(jnp.float32)], axis=1)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_aggregate_kernel, a=a, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, BLOCK_ROWS, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(codes, sw)
