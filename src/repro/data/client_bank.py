"""Device-resident padded client data bank (the batched FL engine's input).

The legacy FL loop re-pads and re-uploads every scheduled device's shard from
host on every round (one ``local_update`` host round-trip per device).  The
bank pays that cost exactly once: all M shards are padded to a common batch
grid and uploaded as two device-resident tensors

    xb: (M, n_batches, batch_size, D)  float32
    yb: (M, n_batches, batch_size)     int32, -1 marks padding

so a round is a K-row gather (``xb[dev_idx]``) inside the jitted round step
instead of K host->device copies.  Padding rows carry label -1, the same
validity convention the legacy SGD epoch masks on, so a shard shorter than
the common grid trains identically to its legacy per-shard padding: the
extra all-padding batches produce exactly-zero gradients and leave the
parameters untouched.

Memory: the bank is the dataset re-laid-out per device plus padding up to
the *largest* shard's batch count, i.e. O(M * max_k ceil(|D_k|/bs) * bs * D)
floats — at paper scale (M=300, MNIST-like) tens of MB.

The same gather idiom serves per-round *evaluation*: :class:`EvalBank`
keeps the test set resident on device, and :func:`eval_sample_plan`
precomputes a seeded (T, n) row-index plan so a client-sampled eval is one
gather + batched forward inside the jitted round step (or the scanned
horizon) — with ``frac = 1`` the gather is skipped entirely and the eval
is bit-identical to the full-test-set ``lenet.accuracy`` call it replaces.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

EVAL_SEED_OFFSET = 23
# decorrelates the eval-sampling stream from the model-init / channel /
# scheduling streams that consume FLConfig.seed (the scheduling permutation
# already claims +17 — see scheduling.RandomPolicy.SEED_OFFSET)


@dataclasses.dataclass
class ClientBank:
    """All M client shards, padded and resident on device."""

    xb: jax.Array        # (M, NB, BS, D) float32
    yb: jax.Array        # (M, NB, BS) int32; -1 marks padding samples
    sizes: np.ndarray    # (M,) realized shard sizes (host, for FedAvg weights)

    @property
    def num_devices(self) -> int:
        return self.xb.shape[0]

    @property
    def batch_size(self) -> int:
        return self.xb.shape[2]

    @staticmethod
    def _ceil_batches(n: int, batch_size: int) -> int:
        """The grid rule: batches needed to cover n samples (min 1)."""
        return max(1, int(-(-int(n) // int(batch_size))))

    def n_batches_for(self, devs) -> int:
        """Batches covering the given devices' shards — the batched engine
        slices the global grid down to this per round (same rule as
        ``build``, single owner), clamped to the bank's own grid."""
        if not len(devs):
            return 1
        need = self._ceil_batches(self.sizes[list(devs)].max(), self.batch_size)
        return min(need, self.xb.shape[1])

    @classmethod
    def build(
        cls, x_train: np.ndarray, y_train: np.ndarray, shards: list,
        batch_size: int,
    ) -> "ClientBank":
        """Pad all shards once to the common (n_batches, batch_size) grid.

        Sample order inside each shard is preserved (shards arrive
        pre-shuffled from the partitioner), so batch b of device k holds
        exactly the samples the legacy ``local_update`` would put there.
        """
        m = len(shards)
        d = x_train.shape[1]
        bs = int(batch_size)
        sizes = np.array([len(s) for s in shards], dtype=np.intp)
        nb = cls._ceil_batches(sizes.max(), bs) if m else 1
        xb = np.zeros((m, nb * bs, d), np.float32)
        yb = np.full((m, nb * bs), -1, np.int32)
        for k, idx in enumerate(shards):
            n = len(idx)
            xb[k, :n] = x_train[idx]
            yb[k, :n] = y_train[idx]
        return cls(
            xb=jnp.asarray(xb.reshape(m, nb, bs, d)),
            yb=jnp.asarray(yb.reshape(m, nb, bs)),
            sizes=sizes,
        )


@dataclasses.dataclass
class EvalBank:
    """The test set, resident on device for gathered per-round evaluation.

    No padding: a sampled eval gathers exactly ``n`` rows (fixed shape per
    horizon), so the masked-accuracy bookkeeping the training bank needs
    never enters the eval path and the ``frac = 1`` case stays bit-identical
    to ``lenet.accuracy`` over the raw arrays.
    """

    xe: jax.Array        # (N, D)
    ye: jax.Array        # (N,)

    @property
    def num_samples(self) -> int:
        return self.xe.shape[0]

    @classmethod
    def build(cls, x_test: np.ndarray, y_test: np.ndarray) -> "EvalBank":
        return cls(xe=jnp.asarray(x_test), ye=jnp.asarray(y_test))


def eval_sample_plan(
    num_test: int, frac: float, num_rounds: int, seed: int
) -> "np.ndarray | None":
    """Seeded (T, n) eval-row gather plan, or ``None`` for a full eval.

    One draw per round for *every* round (not only eval rounds), so the
    per-round driver and the scanned horizon — which may skip different
    rounds under ``eval_every`` — index an identical plan at matching ``t``
    and report identical sampled accuracies.  n = ceil(frac * N), without
    replacement within a round.
    """
    if frac >= 1.0:
        return None
    n = max(1, int(np.ceil(frac * num_test)))
    rng = np.random.default_rng(seed + EVAL_SEED_OFFSET)
    return np.stack(
        [rng.choice(num_test, size=n, replace=False) for _ in range(num_rounds)]
    ).astype(np.int32)
