"""Scheduler edge cases (T*K > M horizons) and the jax backend equivalence.

Regression coverage for the crash/bias sweep: every scheduler must survive
horizons that exhaust the device set (Yang et al. 2019 comparison regime),
emitting empty tail groups instead of crashing; and the device-resident
greedy (``backend="jax"``) must reproduce the numpy path bit-for-bit.
"""
import numpy as np
import pytest

from repro.core import scheduling

NOISE = 1.6e-14


def _instance(m, t, seed):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    return gains, w


def _make(name, gains, w, k):
    if name == "lazy-gwmin":
        return scheduling.lazy_greedy_schedule(gains, w, k, noise_power=NOISE)
    if name == "literal-gwmin":
        return scheduling.literal_graph_schedule(gains, w, k, noise_power=NOISE)
    if name == "random":
        rng = np.random.default_rng(0)
        return scheduling.random_schedule(rng, gains, w, k, noise_power=NOISE)
    if name == "round-robin":
        return scheduling.round_robin_schedule(gains, w, k, noise_power=NOISE)
    if name == "proportional-fair":
        return scheduling.proportional_fair_schedule(gains, w, k, noise_power=NOISE)
    raise ValueError(name)


# --------------------------------------------------------------------------
# T*K > M: the horizon exhausts the device set
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name",
    ["lazy-gwmin", "literal-gwmin", "random", "round-robin", "proportional-fair"],
)
@pytest.mark.parametrize("m,t,k", [(5, 4, 2), (4, 3, 2), (6, 8, 1)])
def test_tk_exceeds_m_no_crash(name, m, t, k):
    """All five schedulers must survive T*K > M: C1/C2 hold, every id is in
    range, and rounds beyond the device supply come back empty, not bogus."""
    gains, w = _instance(m, t, seed=3)
    sched = _make(name, gains, w, k)
    assert sched.validate(m, k)
    assert len(sched.rounds) == t
    assert all(len(grp) <= k for grp in sched.rounds)
    # no device can appear anywhere once all M are used
    assert sum(len(grp) for grp in sched.rounds) <= m
    assert len(sched.scheduled_devices()) == sum(len(g) for g in sched.rounds)


@pytest.mark.parametrize("name", ["round-robin", "proportional-fair"])
def test_exhausting_schedulers_cover_all_devices_then_go_empty(name):
    """The sequential policies schedule every device and then emit () tails
    (proportional-fair used to crash here: an empty ``avail`` built with
    ``np.array([])`` is float64 and rejects fancy indexing)."""
    m, t, k = 4, 3, 2
    gains, w = _instance(m, t, seed=7)
    sched = _make(name, gains, w, k)
    assert sched.scheduled_devices() == set(range(m))
    assert sched.rounds[-1] == ()


def test_proportional_fair_empty_avail_regression():
    """Direct regression for src/repro/core/scheduling.py PF indexing: with
    T*K well past M the scheduler iterates many all-empty rounds."""
    gains, w = _instance(3, 6, seed=0)
    sched = scheduling.proportional_fair_schedule(gains, w, 2, noise_power=NOISE)
    assert sched.validate(3, 2)
    assert sched.rounds[2:] == [(), (), (), ()]


# --------------------------------------------------------------------------
# device backends: fused while_loop and step-wise greedy == numpy, bit for bit
# --------------------------------------------------------------------------

EDGE_GRID = [
    (8, 2, 3, 24, 0),      # pool >= M: full enumeration
    (12, 3, 3, 24, 1),
    (32, 3, 4, 24, 2),     # proxy-ranked pool (M > pool)
    (24, 3, 4, 8, 3),
    (32, 2, 5, 8, 4),
    (5, 2, 4, 24, 5),      # T*K > M: host tail path for leftover groups
    (30, 3, 11, 8, 6),     # T*K > M with proxy pool
    (10, 3, 3, 2, 7),      # pool < K: groups shrink to the pool size
]


@pytest.mark.parametrize("backend", ["jax", "jax-stepwise"])
@pytest.mark.parametrize("m,k,t,pool,seed", EDGE_GRID)
def test_jax_backend_bit_identical(m, k, t, pool, seed, backend):
    pytest.importorskip("jax")
    gains, w = _instance(m, t, seed)
    a = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool, backend=backend
    )
    assert a.rounds == b.rounds
    for pa, pb in zip(a.powers, b.powers):
        np.testing.assert_array_equal(pa, pb)
    for ra, rb in zip(a.rates, b.rates):
        np.testing.assert_array_equal(ra, rb)
    assert a.weighted_sum_rate == b.weighted_sum_rate
    assert b.validate(m, k)


@pytest.mark.parametrize("m,k,t,pool,seed", EDGE_GRID)
def test_fused_equals_stepwise_selection(m, k, t, pool, seed):
    """The fused while_loop driver must walk the exact vertex sequence the
    step-wise driver walks: identical rounds straight out of selection."""
    pytest.importorskip("jax")
    gains, w = _instance(m, t, seed)
    fused = scheduling._lazy_gwmin_rounds(
        gains, w, k, pmax=0.01, noise_power=NOISE, candidate_pool=pool,
        backend="jax",
    )
    stepwise = scheduling._lazy_gwmin_rounds(
        gains, w, k, pmax=0.01, noise_power=NOISE, candidate_pool=pool,
        backend="jax-stepwise",
    )
    assert fused == stepwise


@pytest.mark.parametrize("backend", ["jax", "jax-stepwise"])
def test_jax_backend_bit_identical_with_mapel_refinement(backend):
    """Selection equality carries through the batched MAPEL finalization."""
    pytest.importorskip("jax")
    gains, w = _instance(10, 3, seed=11)
    a = scheduling.lazy_greedy_schedule(
        gains, w, 2, power_mode="mapel", noise_power=NOISE
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, 2, power_mode="mapel", noise_power=NOISE, backend=backend
    )
    assert a.rounds == b.rounds
    for pa, pb in zip(a.powers, b.powers):
        np.testing.assert_array_equal(pa, pb)
    assert a.weighted_sum_rate == b.weighted_sum_rate


def test_unknown_backend_raises():
    gains, w = _instance(6, 2, seed=0)
    with pytest.raises(ValueError, match="backend"):
        scheduling.lazy_greedy_schedule(
            gains, w, 2, noise_power=NOISE, backend="tpu-v9"
        )


# --------------------------------------------------------------------------
# fused-backend switches: pallas scorer and vertex-axis sharding
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,t,pool,seed", [
    (20, 3, 4, 12, 9),
    (32, 2, 5, 8, 4),
    (5, 2, 4, 24, 5),      # T*K > M tail after the fused loop
])
def test_pallas_scorer_agrees_with_xla_scorer(m, k, t, pool, seed):
    """The Pallas SIC kernel scorer accumulates in f32 (ULP-level score
    differences vs the f64 XLA comparison-matrix), but the greedy argmax is
    insensitive on non-degenerate instances: same schedules."""
    pytest.importorskip("jax")
    gains, w = _instance(m, t, seed)
    a = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool, backend="jax",
        scorer="xla",
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool, backend="jax",
        scorer="pallas",
    )
    assert a.rounds == b.rounds
    assert a.weighted_sum_rate == b.weighted_sum_rate


def test_unknown_scorer_raises():
    pytest.importorskip("jax")
    gains, w = _instance(6, 2, seed=0)
    with pytest.raises(ValueError, match="scorer"):
        scheduling.lazy_greedy_schedule(
            gains, w, 2, noise_power=NOISE, backend="jax", scorer="cuda"
        )


@pytest.mark.parametrize("shards", [1, 4])
def test_sharded_fused_loop_bit_identical(shards):
    """shard_map over the vertex axis (in-mesh argmax reduction) must not
    change the schedule.  shards=1 exercises the collective code path on a
    single-device mesh; shards above the local device count clamp (this
    container has one CPU device — multi-shard equality is additionally
    pinned by the forced-host-device run in CI-less environments via
    ``XLA_FLAGS=--xla_force_host_platform_device_count``)."""
    pytest.importorskip("jax")
    gains, w = _instance(24, 4, seed=12)
    a = scheduling.lazy_greedy_schedule(
        gains, w, 3, noise_power=NOISE, candidate_pool=10
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, 3, noise_power=NOISE, candidate_pool=10, backend="jax",
        shards=shards,
    )
    assert a.rounds == b.rounds
    assert a.weighted_sum_rate == b.weighted_sum_rate


# --------------------------------------------------------------------------
# degenerate batch shapes: greedy_step pool > M must match the host clamp
# --------------------------------------------------------------------------

def test_greedy_step_clamps_candidate_pool_beyond_m():
    """Regression: calling the jitted ``greedy_step`` directly with
    pool > M used to be a broadcast-shape crash; the host driver clamps the
    pool to M, and the jitted path must behave identically."""
    jax = pytest.importorskip("jax")
    import itertools

    import jax.numpy as jnp

    from repro.core import rates_jax

    m, t, pool = 6, 3, 16
    gains, w = _instance(m, t, seed=0)
    solo = w * np.log2(1.0 + (0.01 * gains**2) / NOISE)
    with jax.experimental.enable_x64():
        jg = jnp.asarray(gains, jnp.float64)
        jw = jnp.asarray(w, jnp.float64)
        jsolo = jnp.asarray(solo, jnp.float64)
        avail = jnp.ones(m, bool)
        done = jnp.zeros(t, bool)
        subs = jnp.asarray(np.array(
            list(itertools.combinations(range(m), 2)), np.int32))
        big = rates_jax.greedy_step(
            jg, jw, jsolo, subs, avail, done,
            pool=pool, pmax=0.01, noise_power=NOISE)
        ref = rates_jax.greedy_step(
            jg, jw, jsolo, subs, avail, done,
            pool=m, pmax=0.01, noise_power=NOISE)
        assert float(big[0]) == float(ref[0])
        assert int(big[1]) == int(ref[1])
        np.testing.assert_array_equal(np.asarray(big[2]), np.asarray(ref[2]))
        # a naive caller enumerating positions over the unclamped pool gets
        # the out-of-range subsets masked infeasible, not a crash
        subs_naive = jnp.asarray(np.array(
            list(itertools.combinations(range(pool), 2)), np.int32))
        naive = rates_jax.greedy_step(
            jg, jw, jsolo, subs_naive, avail, done,
            pool=pool, pmax=0.01, noise_power=NOISE)
        assert float(naive[0]) == float(ref[0])
        np.testing.assert_array_equal(np.asarray(naive[2]), np.asarray(ref[2]))


def test_lazy_greedy_pool_beyond_m_matches_exact_pool():
    """End-to-end: candidate_pool > M is the full-cell enumeration."""
    gains, w = _instance(7, 3, seed=2)
    a = scheduling.lazy_greedy_schedule(
        gains, w, 2, noise_power=NOISE, candidate_pool=100, backend="jax"
    )
    b = scheduling.lazy_greedy_schedule(
        gains, w, 2, noise_power=NOISE, candidate_pool=7
    )
    assert a.rounds == b.rounds
