"""FLC003 corpus: host sync on traced values inside jit-reachable code.

``float()`` / ``.item()`` / ``np.asarray`` on a traced value forces a
device sync and fails under ``lax.scan`` / ``jit`` tracing; the rule only
fires when the enclosing function is reachable from a jit root through
the lightweight call graph.  Never executed — parsed only.
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_float_in_jit(x):
    s = jnp.sum(x)
    return float(s)  # expect: FLC003


@jax.jit
def bad_item_in_jit(x):
    return jnp.max(x).item()  # expect: FLC003


def _helper(x):
    m = jnp.mean(x)
    return np.asarray(m)  # expect: FLC003


@jax.jit
def bad_reachable_helper(x):
    # _helper is not decorated, but it is reachable from this jit root,
    # so its np.asarray on a traced value fires
    return _helper(x)


def good_static_shape(x):
    # shape/len access is a host int even under tracing
    n = int(x.shape[0])
    return jnp.zeros(n)


def good_host_only(x):
    # identical construct, but never reachable from a jit root
    s = jnp.sum(x)
    return float(s)
