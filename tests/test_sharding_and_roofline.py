"""Sharding rule translation + roofline HLO parsing (no multi-device mesh
needed: translate() only reads mesh.shape)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import roofline as rl
from repro.sharding import rules as sh


class FakeMesh:
    """Stands in for jax.sharding.Mesh: rules only use .shape / contains."""

    def __init__(self, **axes):
        self.shape = axes

    @property
    def size(self):
        out = 1
        for v in self.shape.values():
            out *= v
        return out


MESH = FakeMesh(data=16, model=16)
POD = FakeMesh(pod=2, data=16, model=16)


def test_translate_basic_mapping():
    spec = sh.translate(("embed", "mlp"), (4096, 12288), MESH)
    assert spec == P("data", "model")
    spec = sh.translate(("heads", "kv", "embed"), (32, 128, 4096), MESH)
    assert spec == P("model", None, "data")


def test_translate_divisibility_fallback():
    # 8 experts on a 16-way model axis -> replicated, d_ff takes model
    spec = sh.translate(("expert", "embed", "mlp"), (8, 6144, 16384), MESH)
    assert spec == P(None, "data", "model")
    # 16 experts -> expert parallel, d_ff falls back (axis already used)
    spec = sh.translate(("expert", "embed", "mlp"), (16, 5120, 8192), MESH)
    assert spec == P("model", "data", None)


def test_translate_vocab_tensors_not_fsdp():
    # embedding: vocab sharded, embed dim replicated (perf iteration 0)
    spec = sh.translate(("vocab", "embed"), (152064, 896), MESH)
    assert spec == P("model", None)


def test_translate_no_duplicate_axis():
    spec = sh.translate(("mlp", "heads"), (128, 32), MESH)
    assert spec[0] == "model" and spec[1] is None


def test_batch_axes_multi_pod():
    assert sh.batch_axes(MESH) == ("data",)
    assert sh.batch_axes(POD) == ("pod", "data")
    assert sh.batch_shard(POD) == 32


def test_cache_pspec_batch_vs_seq():
    # decode_32k: batch 128 shards over data
    spec = sh.cache_pspec(MESH, (24, 128, 32768, 16, 128), stacked_dims=1)
    assert spec == P(None, ("data",), None, "model", None)
    # long_500k: batch 1 -> sequence shards instead
    spec = sh.cache_pspec(MESH, (24, 1, 524288, 16, 128), stacked_dims=1)
    assert spec == P(None, None, ("data",), "model", None)


def test_activation_specs():
    assert sh.activation_specs(MESH, 256) == P(("data",), None)
    assert sh.activation_specs(MESH, 1) == P(None, None)


# ---- roofline parsing ----------------------------------------------------

HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,4096]{1,0} parameter(0)
  %ag = f32[16,4096,152064]{1,0,2} all-gather(%p0), dimensions={2}
  %ar = f32[16,4096,896]{2,1,0} all-reduce(%p0), to_apply=%sum
  %tup = (f32[8,8]{1,0}, bf16[4,4]{1,0}) all-reduce(%p0, %p0), to_apply=%sum
  %rs = bf16[2048]{0} reduce-scatter(%p0), dimensions={0}
  %a2a = f32[64,64]{1,0} all-to-all(%p0), dimensions={0}
  %add = f32[16,4096]{1,0} add(%p0, %p0)
}
"""


def test_parse_collectives_kinds_and_bytes():
    stats = rl.parse_collectives(HLO)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.count_by_kind["all-reduce"] == 2
    assert stats.count_by_kind["reduce-scatter"] == 1
    assert stats.count_by_kind["all-to-all"] == 1
    assert stats.bytes_by_kind["all-gather"] == 16 * 4096 * 152064 * 4
    assert stats.bytes_by_kind["all-reduce"] == (
        16 * 4096 * 896 * 4 + 8 * 8 * 4 + 4 * 4 * 2)
    assert stats.bytes_by_kind["reduce-scatter"] == 2048 * 2
    # plain ops not counted
    assert stats.total_bytes < 16 * 4096 * 152064 * 4 * 2


def test_shape_bytes_tuple_and_scalar():
    assert rl._shape_bytes("f32[4,4]{1,0}") == 64
    assert rl._shape_bytes("(f32[2], bf16[2])") == 8 + 4
    assert rl._shape_bytes("pred[8]") == 8


def test_roofline_terms_and_bottleneck():
    from repro.config import INPUT_SHAPES
    from repro.configs import get_config

    cfg = get_config("qwen2-0.5b")
    shape = INPUT_SHAPES["train_4k"]
    r = rl.Roofline(
        flops=1e12, hbm_bytes=1e12, collective_bytes=1e10,
        collectives=rl.CollectiveStats({}, {}),
        model_flops=rl.model_flops(cfg, shape, n_chips=256),
    )
    assert r.t_compute == pytest.approx(1e12 / rl.PEAK_FLOPS)
    assert r.t_memory == pytest.approx(1e12 / rl.HBM_BW)
    assert r.bottleneck == "memory"
    # 6*N*D/chips sanity: ~0.5B params * 6 * 1M tokens / 256
    assert r.model_flops == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096 / 256)


def test_model_flops_moe_uses_active_params():
    from repro.config import INPUT_SHAPES
    from repro.configs import get_config

    cfg = get_config("mixtral-8x22b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
    f = rl.model_flops(cfg, INPUT_SHAPES["train_4k"], n_chips=256)
    assert f == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096 / 256)
