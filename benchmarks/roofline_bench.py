"""Roofline table assembly: reads the dry-run JSONL artifacts produced by
repro.launch.dryrun and prints the per-(arch x shape) table used in
EXPERIMENTS.md §Roofline. Does NOT compile anything itself (runs in seconds;
regenerate the JSONL with the dryrun CLI)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            out.append(json.loads(line))
    return out


def main(fast: bool = False):
    # v2 = post-perf-iteration sweep (activation-sharding constraints);
    # the v1 file is the frozen baseline table.
    roof = load("roofline_v2.jsonl") or load("roofline.jsonl")
    if not roof:
        emit("roofline.missing", 0.0,
             "run: python -m repro.launch.dryrun --all --roofline --out results/roofline.jsonl")
        return
    n_ok = 0
    for r in roof:
        if r["status"] != "OK":
            continue
        n_ok += 1
        s = r["roofline"]
        emit(
            f"roofline.{r['arch']}.{r['shape']}",
            s["t_compute_s"] * 1e6,
            f"bottleneck={s['bottleneck']} "
            f"t_mem_us={s['t_memory_s'] * 1e6:.0f} "
            f"t_coll_us={s['t_collective_s'] * 1e6:.0f} "
            f"useful={s['useful_flops_ratio']:.2f}",
        )
    emit("roofline.pairs_ok", 0.0, str(n_ok))
    for name in ("dryrun_single_pod.jsonl", "dryrun_multi_pod.jsonl"):
        rows = load(name)
        ok = sum(r["status"] == "OK" for r in rows)
        fail = sum(r["status"] == "FAIL" for r in rows)
        skip = sum(r["status"] == "SKIP" for r in rows)
        emit(f"dryrun.{name.split('.')[0]}", 0.0,
             f"ok={ok} fail={fail} skip={skip}")


if __name__ == "__main__":
    main()
