"""Llama-4-Scout-17B-16E: MoE 16 experts top-1 + shared expert, block-local
attention for long context (iRoPE-style chunking)
[hf:meta-llama/Llama-4-Scout-17B-16E]. Early-fusion multimodality is out of
backbone scope (token inputs only; DESIGN.md §4)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    num_experts=16, experts_per_token=1, moe_shared_expert=True,
    attention_chunk=8192, rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ModelConfig(
    name="llama4-smoke", family="moe",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64,
    num_experts=4, experts_per_token=1, moe_shared_expert=True,
    attention_chunk=64,
    source="reduced llama4 family",
)
