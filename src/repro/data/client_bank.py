"""Device-resident padded client data bank (the batched FL engine's input).

The legacy FL loop re-pads and re-uploads every scheduled device's shard from
host on every round (one ``local_update`` host round-trip per device).  The
bank pays that cost exactly once: all M shards are padded to a common batch
grid and uploaded as two device-resident tensors

    xb: (M, n_batches, batch_size, D)  float32
    yb: (M, n_batches, batch_size)     int32, -1 marks padding

so a round is a K-row gather (``xb[dev_idx]``) inside the jitted round step
instead of K host->device copies.  Padding rows carry label -1, the same
validity convention the legacy SGD epoch masks on, so a shard shorter than
the common grid trains identically to its legacy per-shard padding: the
extra all-padding batches produce exactly-zero gradients and leave the
parameters untouched.

Memory: the bank is the dataset re-laid-out per device plus padding up to
the *largest* shard's batch count, i.e. O(M * max_k ceil(|D_k|/bs) * bs * D)
floats — at paper scale (M=300, MNIST-like) tens of MB.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ClientBank:
    """All M client shards, padded and resident on device."""

    xb: jax.Array        # (M, NB, BS, D) float32
    yb: jax.Array        # (M, NB, BS) int32; -1 marks padding samples
    sizes: np.ndarray    # (M,) realized shard sizes (host, for FedAvg weights)

    @property
    def num_devices(self) -> int:
        return self.xb.shape[0]

    @property
    def batch_size(self) -> int:
        return self.xb.shape[2]

    @staticmethod
    def _ceil_batches(n: int, batch_size: int) -> int:
        """The grid rule: batches needed to cover n samples (min 1)."""
        return max(1, int(-(-int(n) // int(batch_size))))

    def n_batches_for(self, devs) -> int:
        """Batches covering the given devices' shards — the batched engine
        slices the global grid down to this per round (same rule as
        ``build``, single owner), clamped to the bank's own grid."""
        if not len(devs):
            return 1
        need = self._ceil_batches(self.sizes[list(devs)].max(), self.batch_size)
        return min(need, self.xb.shape[1])

    @classmethod
    def build(
        cls, x_train: np.ndarray, y_train: np.ndarray, shards: list,
        batch_size: int,
    ) -> "ClientBank":
        """Pad all shards once to the common (n_batches, batch_size) grid.

        Sample order inside each shard is preserved (shards arrive
        pre-shuffled from the partitioner), so batch b of device k holds
        exactly the samples the legacy ``local_update`` would put there.
        """
        m = len(shards)
        d = x_train.shape[1]
        bs = int(batch_size)
        sizes = np.array([len(s) for s in shards], dtype=np.intp)
        nb = cls._ceil_batches(sizes.max(), bs) if m else 1
        xb = np.zeros((m, nb * bs, d), np.float32)
        yb = np.full((m, nb * bs), -1, np.int32)
        for k, idx in enumerate(shards):
            n = len(idx)
            xb[k, :n] = x_train[idx]
            yb[k, :n] = y_train[idx]
        return cls(
            xb=jnp.asarray(xb.reshape(m, nb, bs, d)),
            yb=jnp.asarray(yb.reshape(m, nb, bs)),
            sizes=sizes,
        )
