from repro.sharding.rules import (
    AxisRules,
    DEFAULT_RULES,
    activation_specs,
    cache_pspec,
    param_pspecs,
    translate,
)
