"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-7b]

Uses the reduced same-family config on CPU; the production decode shapes
(decode_32k / long_500k) are exercised via the dry-run.
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-7b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch", str(args.batch),
                "--prompt-len", "32", "--gen", "16"])
