"""Paper §IV experiment driver (the end-to-end example): M=300 devices,
K=3 per round, T=35 rounds, LeNet-300-100, non-iid data — reproducing the
Fig. 5 / Fig. 6 settings.

    PYTHONPATH=src python examples/fl_noma_mnist.py [--fast] \
        [--scheduler NAME] [--power mapel|max|ota-align] \
        [--uplink noma|tdma|ota] [--ota-noise STD] [--ota-threshold FRAC] \
        [--engine batched|legacy] [--pallas-agg] \
        [--horizon per-round|scan] [--seeds N] \
        [--model NAME] [--topk FRAC]

``--scheduler`` accepts any registered policy name (see
``repro.core.scheduling``): the paper's precomputed schedulers
(lazy-gwmin, literal-gwmin, random, round-robin, proportional-fair) and
the online FL-state-aware policies (update-aware, age-fair,
matching-pursuit), which are selected round by round inside the training
loop from the previous rounds' update norms / ages.

``--uplink ota`` switches the round aggregate from digital
decode-and-average to the analog over-the-air superposition
(``repro.core.ota``): scheduled devices transmit simultaneously with
truncated-channel-inversion scaling and the PS receives one noisy sum —
DoReFa quantization and top-k never apply, so the driver forces
``compression="none"``.  ``--ota-noise`` sets the receiver noise std
(0 = the exact weighted aggregate) and ``--ota-threshold`` the
inversion truncation (devices below that fraction of the round's best
channel sit out).  ``--power ota-align`` reports the matching
channel-inversion control-plane powers; ``--scheduler
matching-pursuit`` is the OTA-aware online policy (greedy residual
aggregation-error decrease).  Example:

    PYTHONPATH=src python examples/fl_noma_mnist.py --fast \
        --uplink ota --ota-noise 1e-9 --scheduler matching-pursuit

``--engine`` picks the round-body engine (``FLConfig.fl_engine``):
``batched`` (default here) runs each round as one jitted dispatch over a
device-resident ClientBank — several times faster per round (see
BENCH_fl.json) and equal to the legacy loop to f32 tolerance;
``legacy`` is the per-device oracle loop.  ``--pallas-agg`` sets
``FLConfig.use_pallas``: the batched engine then aggregates through the
fused dequant+aggregate Pallas kernel instead of the XLA einsum
(interpret mode on CPU, Mosaic on TPU).

``--horizon scan`` (``FLConfig.horizon``) runs the whole horizon as ONE
``lax.scan`` device program instead of dispatching round by round —
identical schedules/bits/rates/times, bit-identical accuracies
(tests/test_fl_scan.py).  Online policies run under the scan too, via the
traced selection protocol (tests/test_policy_scan.py; BENCH_policy.json
tracks the speedup) — selection, power allocation and budget pricing all
execute inside the scan body, e.g.::

    PYTHONPATH=src python examples/fl_noma_mnist.py --fast \
        --horizon scan --scheduler update-aware

(the driver then defaults ``--power`` to ``max``: MAPEL's host-iterative
polyblock search cannot run inside the traced round body).
``--seeds N`` additionally sweeps
N independent seeds (model init + channel draws + schedule each) through
``fl.run_horizon_vmapped`` — one vmapped program for the whole sweep —
and reports the mean/std final accuracy; it implies ``--horizon scan``.
Multi-cell grids with the cell axis sharded over a device mesh live in
``fl.run_cell_sweep`` (BENCH_cells.json tracks the sweep speedup).

Model and compression flags (the model-agnostic payload path):

``--model`` picks the FL payload (``FLConfig.model``, resolved through
``repro.models.fl_models``): ``lenet`` (default — the paper's
LeNet-300-100 on MNIST-like images, bit-identical to the historical
hardcoded path), ``tiny-transformer`` / ``tiny-transformer-1m`` (dense
next-token transformers; the ``-1m`` variant is the >=10^6-param
transformer-class payload), or any ``repro.configs`` arch id such as
``qwen2_0_5b:smoke``.  Token models train on a synthetic next-token
corpus (``repro.data.tokens.make_token_dataset``) partitioned with the
same Dirichlet non-iid machinery as the image path.

``--topk`` (< 1.0) turns on top-k sparsification before DoReFa: each
client keeps only the affordable top fraction of update coordinates
under its §IV bit budget (``compression.topk_plan``) and the logged
compression ratios become the honest sparse on-air ratios I / S_k.
Requires the batched engine or the scan horizon (the legacy oracle loop
stays dense).  Example — a transformer-class payload with 1% top-k over
the scanned horizon:

    PYTHONPATH=src python examples/fl_noma_mnist.py --fast \
        --model tiny-transformer-1m --topk 0.01 --horizon scan

Takes ~10-20 min at full scale on this CPU (legacy engine; the batched
engine cuts the round-loop time severalfold); --fast runs M=60, T=10.
"""
import argparse
import contextlib
import os
import sys

import numpy as np

from repro.config import FLConfig
from repro.core import channel, fl, ota, scheduling
from repro.data import dirichlet_partition, make_mnist_like
from repro.data.tokens import make_token_dataset
from repro.models.fl_models import get_fl_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--scheduler", default="lazy-gwmin",
                    choices=scheduling.available_policies())
    ap.add_argument("--power", default=None,
                    help="power mode (default mapel; ota uplink defaults "
                         "to max — MAPEL optimizes SIC decode rates the "
                         "analog sum never performs — as does an online "
                         "scheduler under --horizon scan, whose traced "
                         "round body cannot run the host-iterative "
                         "polyblock search)")
    ap.add_argument("--uplink", default="noma", choices=ota.UPLINK_MODES)
    ap.add_argument("--ota-noise", type=float, default=0.0,
                    help="OTA receiver noise std (uplink=ota; 0 = exact "
                         "weighted aggregate)")
    ap.add_argument("--ota-threshold", type=float, default=0.0,
                    help="truncated channel inversion: devices below this "
                         "fraction of the round's best gain sit out")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--engine", default="batched", choices=["legacy", "batched"])
    ap.add_argument("--pallas-agg", action="store_true",
                    help="batched engine: aggregate via the Pallas kernel")
    ap.add_argument("--horizon", default="per-round",
                    choices=["per-round", "scan"],
                    help="scan: whole horizon as one lax.scan program "
                         "(precomputed schedules, and online policies via "
                         "the traced selection protocol)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="sweep N seeds through one vmapped scan program "
                         "(implies --horizon scan)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model", default="lenet",
                    help="FL payload (FLConfig.model): lenet, "
                         "tiny-transformer, tiny-transformer-1m, or a "
                         "repro.configs arch id ('<id>' / '<id>:smoke')")
    ap.add_argument("--topk", type=float, default=1.0,
                    help="top-k sparsification cap before DoReFa "
                         "(fraction of coordinates kept; 1.0 = dense; "
                         "batched engine / scan horizon only)")
    ap.add_argument("--sanitize-nans", action="store_true",
                    help="run under the flcheck NaN sanitizer "
                         "(jax_debug_nans): a NaN anywhere in the FL math "
                         "raises FloatingPointError at the source instead "
                         "of poisoning the accuracy curve; slow, debug only")
    args = ap.parse_args()
    if args.seeds is not None:
        args.horizon = "scan"
    if args.power is None:
        online_scan = (args.horizon == "scan"
                       and scheduling.policy_is_online(args.scheduler))
        args.power = ("max" if args.uplink == "ota" or online_scan
                      else "mapel")

    m = 60 if args.fast else 300              # paper: M = 300
    t = args.rounds or (10 if args.fast else 35)  # paper: T = 35

    model = get_fl_model(args.model)
    if model.kind == "tokens":
        # synthetic next-token corpus, Dirichlet-partitioned by the rows'
        # pseudo-class so the non-iid shard machinery matches the image path
        ds = make_token_dataset(
            vocab_size=model.cfg.vocab_size,
            num_samples=4000 if args.fast else 12_000,
            seq_len=16, seed=args.seed)
        part_labels = ds.class_train
    else:
        ds = make_mnist_like(num_samples=4000 if args.fast else 12_000,
                             seed=args.seed)
        part_labels = ds.y_train
    cell = channel.CellConfig(num_devices=m)   # paper §IV cell parameters
    shards = dirichlet_partition(part_labels, m, seed=args.seed)
    # the analog sum never decodes per-device payloads: DoReFa / top-k
    # cannot apply under OTA (FLConfig rejects the combo with the reason)
    compression = "none" if args.uplink == "ota" else "adaptive"
    topk = 1.0 if args.uplink == "ota" else args.topk
    cfg = FLConfig(num_devices=m, group_size=3, num_rounds=t,
                   learning_rate=0.01, batch_size=10,   # Table I
                   scheduler=args.scheduler, power_mode=args.power,
                   compression=compression, fl_engine=args.engine,
                   use_pallas=args.pallas_agg, horizon=args.horizon,
                   model=args.model, topk=topk, uplink=args.uplink,
                   ota_noise=args.ota_noise, ota_threshold=args.ota_threshold,
                   seed=args.seed)

    online = scheduling.get_policy(args.scheduler).online
    print(f"M={m} K=3 T={t} scheduler={args.scheduler} power={args.power} "
          f"uplink={args.uplink} engine={args.engine} "
          f"horizon={args.horizon} model={args.model} "
          f"{'topk=' + format(args.topk, '.2f') + ' ' if args.topk < 1 else ''}"
          f"mode={'online (live)' if online else 'precomputed'}")

    if args.sanitize_nans:
        # tools/ sits next to src/ at the repo root, not on the examples/
        # script path argparse launches from
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from tools.flcheck.sanitizers import nan_guard

        guard = nan_guard()
    else:
        guard = contextlib.nullcontext()

    with guard:
        if args.seeds is not None:
            sweep = fl.run_horizon_vmapped(
                ds, shards, cell, cfg,
                seeds=range(args.seed, args.seed + args.seeds),
                uplink=args.uplink)
            finals = np.array([r.accuracies()[-1] for r in sweep])
            for i, r in enumerate(sweep):
                print(f"seed {args.seed + i}: final acc "
                      f"{r.accuracies()[-1]:.3f} "
                      f"sim time {r.times()[-1]:6.1f}s")
            print(f"\n{args.seeds} seeds: final acc {finals.mean():.3f} "
                  f"+/- {finals.std():.3f}")
            return

        res = fl.run_federated_learning(
            ds, shards, cell, cfg, uplink=args.uplink,
            progress=lambda log: print(
                f"round {log.round:3d} acc={log.test_accuracy:.3f} "
                f"bits={log.bits.tolist()} t={log.wall_time_s:6.1f}s"))
    accs = res.accuracies()
    print(f"\nfinal acc {accs[-1]:.3f}; mean-last-5 "
          f"{np.mean(accs[-5:]):.3f}; total sim time {res.times()[-1]:.1f}s")


if __name__ == "__main__":
    main()
