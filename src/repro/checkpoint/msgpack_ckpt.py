"""Checkpointing: pytree <-> msgpack with zstd compression.

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
serialized as nested dicts/lists. Restores onto host then device_put — good
enough for the paper-scale sims and smoke configs (the multi-pod path would
use a sharded writer per host; out of scope for a CPU container, noted in
DESIGN.md).
"""
from __future__ import annotations

import os
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # offline container without the zstd wheel
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


_ARRAY_KEY = "__array__"
_SCALAR_KEY = "__scalar__"


def _encode(node):
    if isinstance(node, (jax.Array, np.ndarray)):
        arr = np.asarray(node)
        return {
            _ARRAY_KEY: True,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    if isinstance(node, (int, float, bool, str)) or node is None:
        return {_SCALAR_KEY: True, "value": node}
    if isinstance(node, dict):
        return {"__dict__": {k: _encode(v) for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {
            "__list__": [_encode(v) for v in node],
            "tuple": isinstance(node, tuple),
        }
    raise TypeError(f"cannot checkpoint node of type {type(node)}")


def _decode(node):
    if _ARRAY_KEY in node:
        arr = np.frombuffer(node["data"], dtype=np.dtype(node["dtype"]))
        return jnp.asarray(arr.reshape(node["shape"]))
    if _SCALAR_KEY in node:
        return node["value"]
    if "__dict__" in node:
        return {k: _decode(v) for k, v in node["__dict__"].items()}
    if "__list__" in node:
        items = [_decode(v) for v in node["__list__"]]
        return tuple(items) if node["tuple"] else items
    raise TypeError(f"bad checkpoint node: {node.keys()}")


def save_checkpoint(path: str, tree) -> None:
    payload = msgpack.packb(_encode(tree), use_bin_type=True)
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(payload)
    else:
        comp = zlib.compress(payload, level=6)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)  # atomic on POSIX


def load_checkpoint(path: str):
    with open(path, "rb") as f:
        comp = f.read()
    # Sniff the frame magic so checkpoints stay readable across containers
    # with and without the zstd wheel.
    if comp[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but the zstandard module is "
                "unavailable in this environment"
            )
        payload = zstandard.ZstdDecompressor().decompress(comp)
    else:
        payload = zlib.decompress(comp)
    return _decode(msgpack.unpackb(payload, raw=False, strict_map_key=False))
