"""FL round-engine benchmark: legacy per-device loop vs batched engine.

Measures the steady-state **round-loop** time of ``fl.run_federated_learning``
(median per-round wall time from the progress callbacks, so setup —
channel sampling, scheduling, ClientBank build, jit compilation — is
excluded) for ``fl_engine in {legacy, batched}`` over the K x M sweep the
batched engine exists for.  ``benchmarks/run.py`` persists the records to
``BENCH_fl.json`` (``BENCH_fl_fast.json`` under --fast/--smoke) so the
round-loop speedup is tracked from PR to PR.

Settings: round-robin scheduling (cheap, deterministic, K devices every
round), max power, adaptive compression, NOMA uplink — the round body is
the only thing that differs between the two engines.

:func:`cells_main` (suite ``fl_cells`` -> ``BENCH_cells.json``) benchmarks
the scanned multi-cell driver instead: a whole cells x seeds instance grid
as ONE ``fl.run_cell_sweep`` device program vs the same instances
dispatched sequentially through the per-round batched driver.
"""
from __future__ import annotations

import dataclasses
import gc
import time

import numpy as np

from benchmarks.common import emit
from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like


def _per_round_seconds(ds, shards, cell, cfg, *, passes: int = 2):
    """Median steady-state round time: warm-compile run, then measure the
    deltas between progress callbacks (covers rounds 1..R-1; setup and the
    round-0 tail of compilation land before the first delta).  Best of
    ``passes`` timed runs, so a background hiccup in one pass does not
    poison the record."""
    fl.run_federated_learning(ds, shards, cell, cfg, eval_every=10**9)
    best = np.inf
    for _ in range(passes):
        ts = []
        fl.run_federated_learning(
            ds, shards, cell, cfg, eval_every=10**9,
            progress=lambda log: ts.append(time.perf_counter()),
        )
        best = min(best, float(np.median(np.diff(ts))))
    return best


def _cells_scanned_s(ds, shards, cell, cfg, cells, seeds, *, passes=2):
    """Wall time of the whole (cells x seeds) sweep as ONE scanned-horizon
    dispatch (fl.run_cell_sweep), warm-compiled; best of ``passes``."""
    fl.run_cell_sweep(ds, shards, cell, cfg, num_cells=cells,
                      seeds_per_cell=seeds, eval_every=10**9)
    best = np.inf
    for _ in range(passes):
        t0 = time.perf_counter()
        fl.run_cell_sweep(ds, shards, cell, cfg, num_cells=cells,
                          seeds_per_cell=seeds, eval_every=10**9)
        best = min(best, time.perf_counter() - t0)
    return best


def _cells_per_round_s(ds, shards, cell, cfg, cells, seeds, *, engine,
                       passes=2):
    """The same cells x seeds instance grid run the pre-scan way: one
    sequential per-round driver call per instance, each paying its own
    setup and T round dispatches.  ``engine = "legacy"`` is the repo's
    default per-round driver (one dispatch per *device* per round);
    ``"batched"`` is the PR 5 engine (one dispatch per round)."""
    base = dataclasses.replace(cfg, horizon="per-round", fl_engine=engine)

    def sweep():
        for c in range(cells):
            for s in range(seeds):
                fl.run_federated_learning(
                    ds, shards, cell,
                    dataclasses.replace(base, seed=cfg.seed + c * seeds + s),
                    eval_every=10**9,
                )

    sweep()   # warm the per-(K, nb) round-step jit cache
    best = np.inf
    for _ in range(passes):
        t0 = time.perf_counter()
        sweep()
        best = min(best, time.perf_counter() - t0)
    return best


def cells_main(fast: bool = False) -> dict:
    """Multi-cell sweep benchmark: scanned cells x seeds grid
    (fl.run_cell_sweep — shared bank, one compiled horizon program) vs
    sequential per-round dispatch of the identical instances, against both
    per-round engines.  ``speedup`` is vs the repo's default per-round
    driver (legacy engine); ``speedup_vs_batched`` isolates what the scan
    adds on top of PR 5's one-dispatch-per-round engine.  Persisted to
    BENCH_cells.json by benchmarks/run.py."""
    if fast:
        cases = [(2, 2, 60, 3)]
        rounds, samples = 3, 1500
    else:
        cases = [(2, 2, 300, 8), (4, 2, 1000, 8), (2, 2, 1000, 8)]
        rounds, samples = 6, 12_000
    records = []
    for cells, seeds, m, k in cases:
        gc.collect()
        ds = make_mnist_like(num_samples=samples, seed=0)
        cell = channel.CellConfig(num_devices=m)
        shards = dirichlet_partition(ds.y_train, m, seed=0)
        cfg = FLConfig(
            num_devices=m, group_size=k, num_rounds=rounds,
            scheduler="round-robin", power_mode="max",
            compression="adaptive", fl_engine="batched", horizon="scan",
            seed=0,
        )
        scan_s = _cells_scanned_s(ds, shards, cell, cfg, cells, seeds)
        batched_s = _cells_per_round_s(ds, shards, cell, cfg, cells, seeds,
                                       engine="batched")
        legacy_s = _cells_per_round_s(ds, shards, cell, cfg, cells, seeds,
                                      engine="legacy")
        speedup = legacy_s / scan_s
        records.append({
            "cells": cells, "seeds": seeds, "m": m, "k": k, "rounds": rounds,
            "scan_sweep_s": scan_s,
            "per_round_legacy_sweep_s": legacy_s,
            "per_round_batched_sweep_s": batched_s,
            "speedup": round(speedup, 2),
            "speedup_vs_batched": round(batched_s / scan_s, 2),
        })
        emit(f"fl.cells_scan_C{cells}_S{seeds}_M{m}_K{k}", scan_s * 1e6)
        emit(f"fl.cells_per_round_C{cells}_S{seeds}_M{m}_K{k}",
             legacy_s * 1e6, f"speedup {speedup:.1f}x")
    return {
        "suite": "fl_cell_sweep",
        "settings": {
            "scheduler": "round-robin", "power_mode": "max",
            "compression": "adaptive", "uplink": "noma",
            "rounds": rounds, "num_samples": samples,
        },
        "records": records,
    }


def main(fast: bool = False) -> dict:
    if fast:
        cases = [(60, 3)]
        rounds, samples = 4, 1500
    else:
        cases = [(m, k) for m in (300, 1000) for k in (3, 8, 16)]
        rounds, samples = 6, 12_000
    records = []
    for m, k in cases:
        gc.collect()   # drop the previous case's dataset + ClientBank now
        ds = make_mnist_like(num_samples=samples, seed=0)
        cell = channel.CellConfig(num_devices=m)
        shards = dirichlet_partition(ds.y_train, m, seed=0)
        cfg = FLConfig(
            num_devices=m, group_size=k, num_rounds=rounds,
            scheduler="round-robin", power_mode="max",
            compression="adaptive", seed=0,
        )
        legacy_s = _per_round_seconds(ds, shards, cell, cfg)
        batched_s = _per_round_seconds(
            ds, shards, cell, dataclasses.replace(cfg, fl_engine="batched")
        )
        speedup = legacy_s / batched_s
        records.append({
            "m": m, "k": k, "rounds": rounds,
            "legacy_s_per_round": legacy_s,
            "batched_s_per_round": batched_s,
            "speedup": round(speedup, 2),
        })
        emit(f"fl.round_legacy_M{m}_K{k}", legacy_s * 1e6)
        emit(f"fl.round_batched_M{m}_K{k}", batched_s * 1e6,
             f"speedup {speedup:.1f}x")
    return {
        "suite": "fl_engine_round_loop",
        "settings": {
            "scheduler": "round-robin", "power_mode": "max",
            "compression": "adaptive", "uplink": "noma",
            "rounds": rounds, "num_samples": samples,
        },
        "records": records,
    }


if __name__ == "__main__":
    main()
