"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Implements the chunked SSD algorithm (the paper's "ssd_minimal_discrete"
reference, restructured for TPU): within-chunk quadratic attention-like
einsums on the MXU, across-chunk linear state recurrence. Decode is the O(1)
per-token recurrence  h <- h*exp(dt*A) + dt*B x ;  y = C.h + D*x.

Layout notes: d_inner = expand * d_model is split into H = d_inner/P heads
(P = ssm_head_dim); B and C are shared across heads per group (G groups).
A depthwise causal conv (width W) runs over concat(x, B, C) as in Mamba2.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import ParamSpec, stacked

SSD_CHUNK = 128


def dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state


def block_schema(cfg):
    d = cfg.d_model
    d_in, h, p_, g, n = dims(cfg)
    conv_dim = d_in + 2 * g * n
    return {
        "ln": L.rmsnorm_schema(d),
        "in_x": ParamSpec((d, d_in), ("embed", "mlp")),
        "in_z": ParamSpec((d, d_in), ("embed", "mlp")),
        "in_b": ParamSpec((d, g * n), ("embed", None)),
        "in_c": ParamSpec((d, g * n), ("embed", None)),
        "in_dt": ParamSpec((d, h), ("embed", "heads")),
        "dt_bias": ParamSpec((h,), ("heads",), init="zeros"),
        "a_log": ParamSpec((h,), ("heads",), init="ssm_a"),
        "d_skip": ParamSpec((h,), ("heads",), init="ones"),
        "conv_w": ParamSpec((cfg.ssm_conv_width, conv_dim), (None, "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "norm_gate": ParamSpec((d_in,), ("mlp",), init="ones"),
        "out": ParamSpec((d_in, d), ("mlp", "embed")),
    }


def schema(cfg, *, shards: int = 16):
    return {
        "embed": L.embedding_schema(cfg.padded_vocab, cfg.d_model, tie=cfg.tie_embeddings),
        "layers": stacked(block_schema(cfg), cfg.num_layers),
        "ln_f": L.rmsnorm_schema(cfg.d_model),
    }


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------

def _segsum(x):
    """segsum(x)[..., i, j] = sum_{j < k <= i} x_k ; -inf above diagonal."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, *, chunk: int = SSD_CHUNK, init_state=None,
                einsum_dtype=jnp.float32):
    """Chunked SSD scan.

    x:  (B, S, H, P)   dt: (B, S, H)   a_log: (H,)
    b, c: (B, S, G, N) ;  heads map to group h % G... (H multiple of G)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).

    einsum_dtype=bfloat16 keeps the O(S*Q) / O(S*N*P) einsum operands in
    bf16 (the decay/cumsum math stays fp32) — §Perf pair A iteration 6.
    """
    bsz, s, h, p_ = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = s // chunk
    ed = einsum_dtype
    a = -jnp.exp(a_log.astype(jnp.float32))                    # (H,)
    da = dt.astype(jnp.float32) * a[None, None, :]             # (B,S,H)
    xd = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # chunk reshapes
    dac = da.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)   # (B,H,C,L)
    xc = xd.astype(ed).reshape(bsz, nc, chunk, h, p_)
    rep = h // g
    bc_ = b.astype(ed).reshape(bsz, nc, chunk, g, n)
    cc_ = c.astype(ed).reshape(bsz, nc, chunk, g, n)
    bh = jnp.repeat(bc_, rep, axis=3)                          # (B,C,L,H,N)
    ch = jnp.repeat(cc_, rep, axis=3)

    da_cs = jnp.cumsum(dac, axis=-1)                           # (B,H,C,L)
    lmat = jnp.exp(_segsum(dac)).astype(ed)                    # (B,H,C,L,L)

    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", ch, bh, lmat, xc,
                        preferred_element_type=jnp.float32)

    decay_states = jnp.exp(da_cs[..., -1:] - da_cs).astype(ed)  # (B,H,C,L)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bh, decay_states, xc,
                        preferred_element_type=jnp.float32)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p_, n), jnp.float32)

    # across-chunk recurrence (sequential scan; nc is small)
    chunk_decay = jnp.exp(da_cs[..., -1])                      # (B,H,C)

    def scan_fn(carry, xs):
        st, dec = xs                                           # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev

    (final, prevs) = jax.lax.scan(
        scan_fn,
        init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prevs.transpose(1, 0, 2, 3, 4)               # (B,C,H,P,N)

    state_decay_out = jnp.exp(da_cs).astype(ed)                # (B,H,C,L)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", ch,
                       prev_states.astype(ed), state_decay_out,
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(bsz, s, h, p_)
    return y, final


def ssd_step(state, x_t, dt_t, a_log, b_t, c_t):
    """O(1) decode recurrence. state (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    b_t, c_t (B,G,N)."""
    h = x_t.shape[1]
    g = b_t.shape[1]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dt_t.astype(jnp.float32) * a[None, :])       # (B,H)
    bh = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)      # (B,H,N)
    ch = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    xd = x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None]
    new = state * dec[..., None, None] + xd[..., None] * bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new, ch)
    return y, new


# --------------------------------------------------------------------------
# Mamba2 block (conv + gating + SSD)
# --------------------------------------------------------------------------

def _causal_conv(u, w, bias):
    """Depthwise causal conv. u: (B,S,C), w: (W,C)."""
    width = w.shape[0]
    up = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(width):
        out = out + up[:, i : i + u.shape[1], :].astype(jnp.float32) * w[i][None, None, :].astype(jnp.float32)
    return out + bias.astype(jnp.float32)


def _conv_step(conv_state, u_t, w, bias):
    """conv_state: (B, W-1, C) past inputs; u_t: (B, C)."""
    width = w.shape[0]
    window = jnp.concatenate([conv_state, u_t[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    return out + bias.astype(jnp.float32), window[:, 1:, :]


def _gated_norm(y, z, scale, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


def mamba_block(p, x, cfg, *, state=None):
    """Full-sequence mamba2 block. x: (B,S,D).

    state: None (training/prefill from scratch) or
    {"ssm": (B,H,P,N), "conv": (B,W-1,conv_dim)} for chunk-wise prefill.
    Returns (out, new_state).
    """
    d_in, h, p_, g, n = dims(cfg)
    bsz, s, _ = x.shape
    xin = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    xc = xin.astype(L.COMPUTE_DTYPE)

    xs = jnp.einsum("bsd,di->bsi", xc, p["in_x"].astype(L.COMPUTE_DTYPE))
    z = jnp.einsum("bsd,di->bsi", xc, p["in_z"].astype(L.COMPUTE_DTYPE))
    bproj = jnp.einsum("bsd,di->bsi", xc, p["in_b"].astype(L.COMPUTE_DTYPE))
    cproj = jnp.einsum("bsd,di->bsi", xc, p["in_c"].astype(L.COMPUTE_DTYPE))
    dt_raw = jnp.einsum("bsd,dh->bsh", xc, p["in_dt"].astype(L.COMPUTE_DTYPE))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    conv_in = jnp.concatenate([xs, bproj, cproj], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs = conv_out[..., :d_in].reshape(bsz, s, h, p_)
    bmat = conv_out[..., d_in : d_in + g * n].reshape(bsz, s, g, n)
    cmat = conv_out[..., d_in + g * n :].reshape(bsz, s, g, n)

    chunk = cfg.ssm_chunk
    pad = (-s) % chunk
    if pad:
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        xs_p, dt_p, b_p, c_p = xs, dt, bmat, cmat
    init_ssm = None if state is None else state["ssm"]
    y, final = ssd_chunked(
        xs_p, dt_p, p["a_log"], b_p, c_p, chunk=chunk, init_state=init_ssm,
        einsum_dtype=L.COMPUTE_DTYPE if cfg.ssm_bf16 else jnp.float32)
    y = y[:, :s]
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in)

    y = _gated_norm(y, z, p["norm_gate"], cfg.norm_eps).astype(L.COMPUTE_DTYPE)
    out = jnp.einsum("bsi,id->bsd", y, p["out"].astype(L.COMPUTE_DTYPE))
    new_state = {"ssm": final, "conv": None}
    if state is not None:
        # keep last W-1 conv inputs for continued decode
        width = cfg.ssm_conv_width
        tail = jnp.concatenate([state["conv"], conv_in.astype(jnp.float32)], axis=1)[:, -(width - 1):, :]
        new_state = {"ssm": final, "conv": tail}
    return out.astype(x.dtype), new_state


def mamba_decode_step(p, x, cfg, state):
    """One-token step. x: (B,1,D). state: {"ssm","conv"}."""
    d_in, h, p_, g, n = dims(cfg)
    bsz = x.shape[0]
    xin = L.rmsnorm(p["ln"], x, cfg.norm_eps)[:, 0]
    xc = xin.astype(L.COMPUTE_DTYPE)
    xs = xc @ p["in_x"].astype(L.COMPUTE_DTYPE)
    z = xc @ p["in_z"].astype(L.COMPUTE_DTYPE)
    bproj = xc @ p["in_b"].astype(L.COMPUTE_DTYPE)
    cproj = xc @ p["in_c"].astype(L.COMPUTE_DTYPE)
    dt_raw = xc @ p["in_dt"].astype(L.COMPUTE_DTYPE)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    conv_in = jnp.concatenate([xs, bproj, cproj], axis=-1)     # (B, conv_dim)
    conv_out, new_conv = _conv_step(state["conv"], conv_in, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    x_t = conv_out[:, :d_in].reshape(bsz, h, p_)
    b_t = conv_out[:, d_in : d_in + g * n].reshape(bsz, g, n)
    c_t = conv_out[:, d_in + g * n :].reshape(bsz, g, n)

    y, new_ssm = ssd_step(state["ssm"], x_t, dt, p["a_log"], b_t, c_t)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * x_t.astype(jnp.float32)
    y = y.reshape(bsz, d_in)
    y = _gated_norm(y, z, p["norm_gate"], cfg.norm_eps).astype(L.COMPUTE_DTYPE)
    out = (y @ p["out"].astype(L.COMPUTE_DTYPE)).astype(x.dtype)
    return out[:, None, :], {"ssm": new_ssm, "conv": new_conv}


def init_state(cfg, batch: int):
    d_in, h, p_, g, n = dims(cfg)
    conv_dim = d_in + 2 * g * n
    return {
        "ssm": jnp.zeros((batch, h, p_, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.float32),
    }


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------

def forward(params, tokens, cfg, *, caches=None, remat: bool = True,
            unroll: bool = False, **_):
    x = L.embed(params["embed"], tokens)

    if caches is not None and tokens.shape[1] == 1:
        def body(x, xs):
            p_layer, st = xs
            y, new_st = mamba_decode_step(p_layer, x, cfg, st)
            return x + y, new_st

        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches),
                                     unroll=unroll)
    else:
        def body(x, xs):
            p_layer, st = xs
            y, new_st = mamba_block(p_layer, x, cfg, state=st)
            return x + y, new_st

        fn = jax.checkpoint(body) if (remat and caches is None) else body
        x, new_caches = jax.lax.scan(fn, x, (params["layers"], caches),
                                     unroll=unroll)

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, tie=cfg.tie_embeddings)
    return logits, new_caches


def loss_fn(params, batch, cfg, **kw):
    logits, _ = forward(params, batch["tokens"], cfg, **kw)
    return L.cross_entropy(logits, batch["labels"], vocab_size=cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int, *, shards: int = 16):
    one = init_state(cfg, batch)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers, *x.shape)), one
    )


def decode_step(params, caches, tokens, cfg, *, unroll: bool = False, **_):
    return forward(params, tokens, cfg, caches=caches, remat=False, unroll=unroll)
