"""Paper Fig. 5: testing accuracy vs communication time — NOMA+compression
FedAvg vs TDMA FedAvg (both max-power, both greedily scheduled).

Paper claim to validate: the NOMA scheme reaches a given accuracy in roughly
half the wall-clock of TDMA (paper: ~70% at ~10 s vs ~22 s on real MNIST;
absolute accuracies differ on the synthetic set — see DESIGN.md §6.1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import World, build_world, emit, timeit
from repro.config import FLConfig
from repro.core import fl


def run(world: World, *, rounds: int, seed: int = 0):
    cfg = FLConfig(num_devices=world.cell.num_devices, group_size=3,
                   num_rounds=rounds, scheduler="lazy-gwmin",
                   power_mode="max", compression="adaptive", seed=seed)
    noma = fl.run_federated_learning(world.dataset, world.shards, world.cell,
                                     cfg, uplink="noma")
    tdma = fl.run_federated_learning(world.dataset, world.shards, world.cell,
                                     cfg, uplink="tdma")
    return noma, tdma


def time_to_accuracy(res, target: float):
    for log in res.logs:
        if log.test_accuracy >= target:
            return log.wall_time_s
    return np.inf


def main(fast: bool = False):
    world = build_world(num_devices=60 if fast else 150,
                        num_samples=3000 if fast else 6000)
    rounds = 8 if fast else 20
    import time as _t

    t0 = _t.perf_counter()
    noma, tdma = run(world, rounds=rounds)
    us = (_t.perf_counter() - t0) * 1e6

    acc_n, acc_t = noma.accuracies(), tdma.accuracies()
    target = 0.95 * max(acc_n.max(), acc_t.max())
    tn, tt = time_to_accuracy(noma, target), time_to_accuracy(tdma, target)
    emit("fig5.noma_final_acc", us, f"{acc_n[-1]:.3f}")
    emit("fig5.tdma_final_acc", us, f"{acc_t[-1]:.3f}")
    emit("fig5.noma_time_to_target_s", us, f"{tn:.1f}")
    emit("fig5.tdma_time_to_target_s", us, f"{tt:.1f}")
    emit("fig5.speedup", us, f"{tt / tn:.2f}" if np.isfinite(tn) else "inf")
    # paper-shape check: NOMA should reach the target no later than TDMA
    assert tn <= tt * 1.05, (tn, tt)
    return {"noma": acc_n, "tdma": acc_t, "t_noma": noma.times(),
            "t_tdma": tdma.times()}


if __name__ == "__main__":
    main()
