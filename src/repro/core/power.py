"""Power allocation for one scheduled NOMA group (paper §III-C).

The weighted sum-rate objective for a fixed decode order is

    max_p  prod_k ( mu_k(p) / phi_k(p) )^{w_k}
    s.t.   0 <= p_k <= p_k^max

with mu_k(p) = sum_{j>=k} p_j h_j^2 + sigma^2 and phi_k = sum_{j>k} p_j h_j^2
+ sigma^2, i.e. z_k := mu_k/phi_k = 1 + SINR_k.  This is a multiplicative
linear fractional program (MLFP); the paper solves it with the MAPEL polyblock
outer-approximation algorithm [Qian et al., 2009].

Key structural fact used throughout (and by the tests): for a *fixed decode
order* and target ratios z_k >= 1, the minimal power vector achieving them is
closed form, solving Eq. (13) back-to-front:

    p_K = (z_K - 1) sigma^2 / h_K^2
    p_k = (z_k - 1) (sum_{j>k} p_j h_j^2 + sigma^2) / h_k^2.

A z-target is feasible iff this minimal p lies in the power box. MAPEL then
reduces to a monotone optimization over the normal set of feasible z vectors,
implemented below with polyblock vertices kept in float64 on the host (this is
control-plane math: K <= 4, a few hundred iterations).

Decode order: following the uplink-NOMA convention (and the paper's WLOG
sorting) we fix the decode order by channel gain, strongest first.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import rates as rates_lib


@dataclasses.dataclass
class PowerSolution:
    powers: np.ndarray          # (K,) allocated powers, input (unsorted) order
    weighted_rate: float        # sum_k w_k log2(1 + SINR_k)
    iterations: int
    gap: float                  # polyblock optimality gap (objective domain)


def _objective(z: np.ndarray, weights: np.ndarray) -> float:
    """prod z_k^{w_k}, evaluated in log-domain for stability."""
    return float(np.exp(np.sum(weights * np.log(np.maximum(z, 1e-300)))))


def min_powers_for_targets(
    z: np.ndarray, gains_sorted: np.ndarray, noise_power: float
) -> np.ndarray:
    """Minimal powers (decode order) achieving ratio targets z (>=1)."""
    k = len(z)
    p = np.zeros(k, dtype=np.float64)
    interference = noise_power
    for i in range(k - 1, -1, -1):
        p[i] = (z[i] - 1.0) * interference / (gains_sorted[i] ** 2)
        interference += p[i] * gains_sorted[i] ** 2
    return p


def feasible(z: np.ndarray, gains_sorted, pmax, noise_power) -> bool:
    if np.any(z < 1.0):
        return False
    p = min_powers_for_targets(z, gains_sorted, noise_power)
    return bool(np.all(p <= pmax * (1.0 + 1e-12)))


def _project(z: np.ndarray, gains_sorted, pmax, noise_power, tol=1e-12):
    """MAPEL projection: largest lam in (0,1] with 1 + lam*(z-1) feasible.

    We project along the ray in (z - 1) (= SINR) space which keeps the
    projection inside the box [1, z] and preserves the polyblock invariants.
    """
    lo, hi = 0.0, 1.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if feasible(1.0 + mid * (z - 1.0), gains_sorted, pmax, noise_power):
            lo = mid
        else:
            hi = mid
        if hi - lo < tol:
            break
    return 1.0 + lo * (z - 1.0)


def _coordinate_polish(p0, gains, weights, pmax, noise_power,
                       *, rounds: int = 4, points: int = 33) -> np.ndarray:
    """Deterministic coordinate ascent on the box (polishes the MAPEL
    incumbent; the polyblock gives the global-optimality certificate, the
    polish closes the outer-approximation tail quickly for K <= 4)."""
    p = np.array(p0, dtype=np.float64)
    grid = np.linspace(0.0, pmax, points)
    for _ in range(rounds):
        improved = False
        for k in range(len(p)):
            best_v, best_pk = weighted_rate(p, gains, weights, noise_power), p[k]
            for cand in grid:
                p[k] = cand
                v = weighted_rate(p, gains, weights, noise_power)
                if v > best_v + 1e-12:
                    best_v, best_pk = v, cand
                    improved = True
            p[k] = best_pk
        if not improved:
            break
    return p


def mapel(
    gains: np.ndarray,
    weights: np.ndarray,
    pmax: float,
    noise_power: float,
    *,
    eps: float = 1e-3,
    max_iter: int = 300,
) -> PowerSolution:
    """MAPEL polyblock algorithm for the weighted sum-rate MLFP.

    gains, weights: (K,) in arbitrary (input) order. Returns powers in the
    same input order. eps is the relative optimality gap on the objective.
    The polyblock loop is capped at ``max_iter`` vertex expansions and the
    incumbent is finished with a coordinate-ascent polish (the raw outer
    approximation converges slowly near the boundary; see tests/test_power).
    """
    gains = np.asarray(gains, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    k = len(gains)
    order = np.argsort(-gains)              # decode order: strongest first
    g = gains[order]
    w = weights[order]

    if k == 1:
        p = np.array([pmax])
        z = 1.0 + p[0] * g[0] ** 2 / noise_power
        rate = float(w[0] * np.log2(z))
        out = np.zeros(1)
        out[order] = p
        return PowerSolution(out, rate, 0, 0.0)

    # Initial polyblock vertex: interference-free upper bound on each z_k.
    z_top = 1.0 + pmax * g**2 / noise_power
    vertices = [z_top]
    best_z = _project(z_top, g, pmax, noise_power)
    best_val = _objective(best_z, w)
    # Seed the incumbent with the all-max-power corner (often optimal in the
    # noise-limited regime of the paper's cell).
    z_corner = _z_of_powers(np.full(k, pmax), g, noise_power)
    if _objective(z_corner, w) > best_val:
        best_z, best_val = z_corner, _objective(z_corner, w)

    it = 0
    gap = np.inf
    while it < max_iter and vertices:
        it += 1
        vals = np.array([_objective(v, w) for v in vertices])
        i_best = int(np.argmax(vals))
        v = vertices.pop(i_best)
        ub = vals[i_best]
        gap = (ub - best_val) / max(best_val, 1e-12)
        if gap <= eps:
            break
        proj = _project(v, g, pmax, noise_power)
        val = _objective(proj, w)
        if val > best_val:
            best_val, best_z = val, proj
        # Split the vertex: v_j -> proj_j along each coordinate.
        for j in range(k):
            if proj[j] < v[j] - 1e-12:
                nv = v.copy()
                nv[j] = proj[j]
                vertices.append(nv)
        # Prune vertices that cannot beat the incumbent.
        vertices = [u for u in vertices if _objective(u, w) > best_val * (1 + eps / 4)]

    p_sorted = np.minimum(
        min_powers_for_targets(best_z, g, noise_power), pmax
    )
    # polish from two starts (polyblock incumbent + max-power corner): the
    # coordinate ascent is exact along axes but can sit in a basin when the
    # incumbent projection landed far from the optimum face.
    cands = [
        _coordinate_polish(p_sorted, g, w, pmax, noise_power),
        _coordinate_polish(np.full(k, pmax), g, w, pmax, noise_power),
    ]
    p_sorted = max(cands, key=lambda p: weighted_rate(p, g, w, noise_power))
    powers = np.zeros(k)
    powers[order] = p_sorted
    # Recompute the achieved weighted rate from the actual powers.
    rate = weighted_rate(powers, gains, weights, noise_power)
    return PowerSolution(powers, rate, it, float(max(gap, 0.0)))


def _z_of_powers(p, gains_sorted, noise_power):
    k = len(p)
    z = np.empty(k)
    for i in range(k):
        mu = np.sum(p[i:] * gains_sorted[i:] ** 2) + noise_power
        phi = np.sum(p[i + 1 :] * gains_sorted[i + 1 :] ** 2) + noise_power
        z[i] = mu / phi
    return z


def max_power(gains: np.ndarray, pmax: float) -> np.ndarray:
    """No-power-control baseline: everyone transmits at p^max (paper §IV)."""
    return np.full(len(np.atleast_1d(gains)), pmax, dtype=np.float64)


def weighted_rate(powers, gains, weights, noise_power) -> float:
    """sum_k w_k log2(1 + SINR_k) under SIC, input order.

    Thin wrapper over the shared batched engine (repro.core.rates) so MAPEL,
    the schedulers, and the kernels all agree on one SIC rate definition.
    """
    return rates_lib.weighted_rate(powers, gains, weights, noise_power)


def grid_oracle(
    gains, weights, pmax, noise_power, *, points: int = 40
) -> PowerSolution:
    """Brute-force grid search oracle (tests only; exponential in K)."""
    gains = np.asarray(gains, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    k = len(gains)
    axes = [np.linspace(0.0, pmax, points) for _ in range(k)]
    best, best_p = -np.inf, None
    grid = np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1).reshape(-1, k)
    for p in grid:
        val = weighted_rate(p, gains, weights, noise_power)
        if val > best:
            best, best_p = val, p
    return PowerSolution(np.asarray(best_p), float(best), len(grid), 0.0)
