"""Mamba2-130M: pure SSM with SSD (state-space duality) [arXiv:2405.21060]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=256, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    tie_embeddings=True,
    source="reduced mamba2 family",
)
