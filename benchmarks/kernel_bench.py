"""Gradient-codec kernel microbenchmarks (paper §II-B compute hot-spot).

On this CPU container the Pallas path runs in interpret mode (Python), so
the jnp/XLA path is the production-CPU number; the interpret number only
validates the kernel wiring. On TPU the pallas_call path is the deployed
one."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import rates
from repro.kernels import ops

N = 1_048_576  # ~1M params (4 MiB f32), LeNet-scale x4


def main(fast: bool = False):
    n = N // 8 if fast else N
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))

    for bits in (1, 8):
        out = ops.quantize_dequantize(x, bits)  # compile
        us = timeit(lambda: ops.quantize_dequantize(x, bits).block_until_ready())
        emit(f"kernel.qdq_b{bits}_xla", us, f"{n} elems")

    codes, scale = ops.quantize_pack(x, 8)
    us = timeit(lambda: ops.quantize_pack(x, 8)[0].block_until_ready())
    emit("kernel.quantize_pack_xla", us, f"{n} elems")
    us = timeit(
        lambda: ops.unpack_dequantize(codes, scale, 8, n).block_until_ready())
    emit("kernel.dequantize_xla", us, f"{n} elems")

    k = 3
    stack = jnp.stack([codes] * k)
    scales = jnp.full((k,), float(scale))
    w = jnp.full((k,), 1.0 / k)
    out = ops.weighted_aggregate(stack, scales, w, 8)
    us = timeit(
        lambda: ops.weighted_aggregate(stack, scales, w, 8).block_until_ready())
    emit("kernel.aggregate_k3_xla", us, f"{n} elems")

    # pallas interpret (validation path; slow by construction on CPU)
    small = x[: 131_072]
    out = ops.quantize_dequantize(small, 8, use_pallas=True)
    us = timeit(
        lambda: ops.quantize_dequantize(small, 8, use_pallas=True)
        .block_until_ready(), repeats=1)
    emit("kernel.qdq_b8_pallas_interpret", us, f"{small.size} elems")

    # batched SIC group scoring (scheduler candidate batches, K=3)
    v = 8_192 if fast else 65_536
    rng = np.random.default_rng(0)
    g_vk = np.abs(rng.normal(1e-6, 5e-7, (v, 3))) + 1e-8
    p_vk = np.full((v, 3), 0.01)
    w_vk = rng.dirichlet(np.ones(3), size=v)
    noise = 1.6e-14
    us = timeit(lambda: rates.batched_weighted_rates(p_vk, g_vk, w_vk, noise))
    emit("kernel.sic_rates_numpy", us, f"{v} groups")
    pj, gj, wj = jnp.asarray(p_vk), jnp.asarray(g_vk), jnp.asarray(w_vk)
    out = ops.sic_weighted_rates(pj, gj, wj, noise)  # compile
    us = timeit(
        lambda: ops.sic_weighted_rates(pj, gj, wj, noise).block_until_ready())
    emit("kernel.sic_rates_xla", us, f"{v} groups")
    vp = 2_048
    out = ops.sic_weighted_rates(
        pj[:vp], gj[:vp], wj[:vp], noise, use_pallas=True)
    us = timeit(
        lambda: ops.sic_weighted_rates(
            pj[:vp], gj[:vp], wj[:vp], noise, use_pallas=True
        ).block_until_ready(), repeats=1)
    emit("kernel.sic_rates_pallas_interpret", us, f"{vp} groups")


if __name__ == "__main__":
    main()
