from repro.checkpoint.msgpack_ckpt import load_checkpoint, save_checkpoint
