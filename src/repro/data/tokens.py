"""Synthetic token pipeline for the LLM-scale trainer.

Generates reproducible pseudo-text token streams with a power-law unigram
distribution plus a short-range bigram structure, so perplexity decreases
measurably during smoke training (pure-uniform tokens would give a flat
loss and hide wiring bugs).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipfian unigram over a capped support for cheap sampling.
        support = min(v, 4096)
        ranks = np.arange(1, support + 1)
        probs = 1.0 / ranks**1.1
        self._support = support
        self._probs = probs / probs.sum()
        # Deterministic "grammar": each token prefers a successor band.
        self._succ = rng.integers(0, support, size=support)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        base = rng.choice(self._support, size=(batch, seq), p=self._probs)
        # 50% of positions follow the bigram successor of the previous token.
        follow = rng.random((batch, seq)) < 0.5
        out = base.copy()
        out[:, 1:] = np.where(
            follow[:, 1:], self._succ[out[:, :-1]], base[:, 1:]
        )
        return out.astype(np.int32)


def synthetic_token_batches(
    vocab_size: int, batch: int, seq: int, *, seed: int = 0
):
    """Infinite iterator of (tokens, labels) next-token-prediction batches."""
    stream = TokenStream(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = stream.sample(rng, batch, seq + 1)
        yield toks[:, :-1], toks[:, 1:]
