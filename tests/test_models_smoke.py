"""Per-architecture smoke tests (deliverable f): every assigned arch's
reduced-family config runs one forward + one train step + one decode step on
CPU with finite outputs and correct shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.optim import adam

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["img_feats"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc_feats"] = jax.random.normal(
            key, (B, 16, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512 and cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    out = model.forward(params, batch, remat=False)
    logits = out[0]
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_improves_or_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adam(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(model, opt, fl_bits=8))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    p1, s1, loss1 = step(params, opt_state, batch)
    p2, s2, loss2 = step(p1, s1, batch)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p1))
    )
    assert moved, f"{arch}: train step did not update params"
    # same batch twice: loss should not explode
    assert float(loss2) < float(loss1) * 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    caches = model.init_cache(B, S + 4)
    dbatch = dict(batch)
    kw = {}
    if cfg.family == "encdec":
        from repro.models import encdec

        enc_out = encdec.encode(params, batch["enc_feats"], cfg)
        dbatch["enc_out"] = enc_out
        kw["enc_out"] = enc_out
    if cfg.family == "vlm":
        kw["img_feats"] = batch["img_feats"]
    out = model.module.forward(params, batch["tokens"][:, : S - 1], cfg,
                               caches=caches, remat=False, **kw)
    logits, caches = out[0], out[1]
    step_logits, caches = model.decode_step(
        params, caches, batch["tokens"][:, S - 1 : S], batch=dbatch)
    assert step_logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(step_logits)))


@pytest.mark.parametrize("arch", ["qwen3_8b", "mamba2_130m", "zamba2_7b",
                                  "seamless_m4t_medium", "llama_3_2_vision_90b"])
def test_decode_matches_full_forward(arch):
    """Incremental decode == teacher-forced full forward (exact for
    non-MoE; MoE differs only via capacity drops, tested separately)."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    kw = {}
    dbatch = dict(batch)
    if cfg.family == "encdec":
        from repro.models import encdec

        enc_out = encdec.encode(params, batch["enc_feats"], cfg)
        kw["enc_out"] = enc_out
        dbatch["enc_out"] = enc_out
    if cfg.family == "vlm":
        kw["img_feats"] = batch["img_feats"]

    full = model.forward(params, batch, remat=False)[0]
    caches = model.init_cache(B, S + 4)
    out = model.module.forward(params, batch["tokens"][:, : S - 1], cfg,
                               caches=caches, remat=False, **kw)
    caches = out[1]
    step_logits, _ = model.decode_step(
        params, caches, batch["tokens"][:, S - 1 : S], batch=dbatch)
    err = float(jnp.max(jnp.abs(step_logits[:, 0] - full[:, -1])))
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-6
    assert err <= 0.05 * scale + 0.05, f"{arch}: decode mismatch {err}"


@pytest.mark.parametrize("arch", ["mixtral_8x22b", "llama4_scout_17b_a16e"])
def test_moe_decode_exact_without_drops(arch, monkeypatch):
    """Decode == teacher-forced forward when capacity drops are impossible.

    Run in fp32 compute: at bf16, 1-ulp reassociation differences between
    the two compiled programs can flip near-tied top-k router decisions
    (the well-known MoE prefill/decode routing fragility) — a numerics
    property, not a caching bug; the caching logic is what this test pins."""
    from repro.models import layers as L

    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    cfg = dataclasses.replace(get_smoke(arch), capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": toks}, remat=False)[0]
    caches = model.init_cache(B, S + 4)
    out = model.module.forward(params, toks[:, : S - 1], cfg, caches=caches,
                               remat=False)
    logits, _ = model.decode_step(params, out[1], toks[:, S - 1 : S])
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32), np.asarray(full[:, -1], np.float32),
        atol=2e-4, rtol=2e-4)


def test_unroll_matches_scan():
    cfg = get_smoke("qwen3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    a = model.module.forward(params, toks, cfg, unroll=True, remat=False)[0]
    b = model.module.forward(params, toks, cfg, unroll=False, remat=False)[0]
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=0.06, rtol=0.05)  # bf16 reassociation


def test_sliding_window_masks_distant_tokens():
    """Mixtral SWA: token attends only within the window."""
    from repro.models.layers import AttnMaskSpec, _mask_block

    q = jnp.arange(8)
    k = jnp.arange(8)
    m = _mask_block(q, k, AttnMaskSpec(causal=True, window=3))
    m = np.asarray(m)
    assert m[7, 5] and m[7, 7]
    assert not m[7, 4] and not m[7, 0]  # outside window
    assert not m[0, 1]  # causal


def test_block_local_attention_chunking():
    from repro.models.layers import AttnMaskSpec, _mask_block

    q = jnp.arange(8)
    k = jnp.arange(8)
    m = np.asarray(_mask_block(q, k, AttnMaskSpec(causal=True, block_local=4)))
    assert m[3, 0] and not m[4, 3]  # chunk boundary at 4


def test_lenet_param_count_matches_paper():
    from repro.models import lenet
    from repro.models.params import init_params
    from repro.utils.tree import tree_count

    params = init_params(lenet.schema(), jax.random.PRNGKey(0))
    assert tree_count(params) == 266_610  # paper §IV


def test_grad_accum_equivalent():
    """Microbatched gradient accumulation == single-shot step (perf lever
    used by the dry-run for train shapes; EXPERIMENTS.md §Perf)."""
    from repro.optim import sgd

    cfg = get_smoke("qwen2_0_5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = sgd(0.1)
    st = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    p1, _, l1 = jax.jit(steps_lib.make_train_step(model, opt))(params, st, batch)
    p4, _, l4 = jax.jit(steps_lib.make_train_step(model, opt, grad_accum=4))(
        params, st, batch)
    assert float(l1) == pytest.approx(float(l4), rel=1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        # f32 summation-order noise only
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
