"""Algorithm 2 scaling: literal graph vs lazy column generation, the batched
SIC rate engine vs the seed's per-subset Python loop, the greedy's optimality
gap vs brute force (paper §III), and the numpy-vs-jax backend sweep whose
records ``benchmarks/run.py`` persists to ``BENCH_scheduling.json`` so the
scheduler perf trajectory is tracked PR over PR."""
from __future__ import annotations

import itertools
import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import scheduling

NOISE = 1.6e-14
PMAX = 0.01


def _instance(m, t, seed=0):
    rng = np.random.default_rng(seed)
    gains = np.abs(rng.normal(1e-6, 5e-7, (t, m))) + 1e-8
    w = rng.dirichlet(np.ones(m))
    return gains, w


def _loop_score(subs_vk, t, gains, w, power_fn):
    """The seed's candidate scorer: one group_weighted_rate call per subset."""
    return np.array([
        scheduling.group_weighted_rate(tuple(s), t, gains, w, power_fn, NOISE)[0]
        for s in subs_vk
    ])


def _candidate_scoring(fast: bool):
    """Batched engine vs per-subset loop on one round's candidate batch:
    M=300, K=3, pool of the 64 strongest -> C(64,3) = 41664 subsets."""
    pool = 32 if fast else 64
    gains, w = _instance(300, 1)
    power_fn = scheduling.make_power_fn("max", PMAX, NOISE)
    solo = w * np.log2(1.0 + (PMAX * gains[0] ** 2) / NOISE)
    keep = np.argsort(-solo)[:pool]
    subs = np.array(
        list(itertools.combinations(sorted(keep.tolist()), 3)), dtype=np.intp
    )
    us_loop = timeit(lambda: _loop_score(subs, 0, gains, w, power_fn), repeats=1)
    us_batch = timeit(
        lambda: scheduling.score_subsets(subs, 0, gains, w, power_fn, NOISE),
        repeats=3,
    )
    vals_loop = _loop_score(subs, 0, gains, w, power_fn)
    vals_batch = scheduling.score_subsets(subs, 0, gains, w, power_fn, NOISE)
    assert np.allclose(vals_loop, vals_batch, rtol=1e-12)
    emit(f"sched.score_loop_M300_pool{pool}", us_loop, f"{len(subs)} subsets")
    emit(
        f"sched.score_batched_M300_pool{pool}",
        us_batch,
        f"speedup {us_loop / us_batch:.1f}x",
    )


def _schedule_once(backend, gains, w, k, pool):
    if backend.startswith("jax"):
        # untimed warm-up: each (T, V, K) case shape compiles the jitted
        # step / fused loop once, and compile latency would otherwise
        # pollute the tracked per-schedule wall-clock
        scheduling.lazy_greedy_schedule(
            gains, w, k, noise_power=NOISE, candidate_pool=pool, backend=backend
        )
    t0 = time.perf_counter()
    s = scheduling.lazy_greedy_schedule(
        gains, w, k, noise_power=NOISE, candidate_pool=pool, backend=backend
    )
    return time.perf_counter() - t0, s


def backend_sweep(fast: bool):
    """M sweep x backend wall-clock for the lazy greedy (BENCH_scheduling.json).

    The numpy path re-enumerates C(pool, K) subsets per (step, round) in
    Python; "jax-stepwise" scores the whole (T, V, K) vertex tensor in one
    jitted call per greedy step but syncs the argmax scalars to the host
    every step; "jax" (fused) runs the entire selection loop inside a single
    ``lax.while_loop`` and syncs exactly once per schedule — the sweep
    measures the host-sync win directly.  M=3000 is device-only — the host
    path is impractical there, which is the point of the device-resident
    backend.
    """
    records = []
    cases = (
        [(100, 10, 3, 32, ("numpy", "jax-stepwise", "jax"))]
        if fast
        else [
            (300, 35, 3, 64, ("numpy", "jax-stepwise", "jax")),
            (1000, 50, 3, 64, ("numpy", "jax-stepwise", "jax")),
            (3000, 50, 3, 64, ("jax-stepwise", "jax")),
        ]
    )
    for m, t, k, pool, backends in cases:
        gains, w = _instance(m, t, seed=0)
        secs = {}
        for backend in backends:
            dt, s = _schedule_once(backend, gains, w, k, pool)
            s.validate(m, k)
            secs[backend] = dt
            records.append({
                "m": m, "t": t, "k": k, "pool": pool, "backend": backend,
                "seconds": round(dt, 4),
                "weighted_sum_rate": float(s.weighted_sum_rate),
            })
            emit(f"sched.lazy_{backend}_M{m}_T{t}_pool{pool}", dt * 1e6,
                 f"wsum {s.weighted_sum_rate:.3f}")
        if "numpy" in secs and "jax" in secs:
            emit(f"sched.backend_speedup_M{m}", 0.0,
                 f"{secs['numpy'] / secs['jax']:.1f}x fused jax over numpy")
        if "jax-stepwise" in secs and "jax" in secs:
            emit(f"sched.fused_vs_stepwise_M{m}", 0.0,
                 f"{secs['jax-stepwise'] / secs['jax']:.2f}x fused over "
                 f"stepwise (host-sync win)")
    # equality spot check on an instance small enough for every path
    g_eq, w_eq = _instance(48, 6, seed=1)
    a = scheduling.lazy_greedy_schedule(
        g_eq, w_eq, 3, noise_power=NOISE, candidate_pool=16
    )
    identical = True
    for backend in ("jax", "jax-stepwise"):
        b = scheduling.lazy_greedy_schedule(
            g_eq, w_eq, 3, noise_power=NOISE, candidate_pool=16, backend=backend
        )
        identical = identical and bool(
            a.rounds == b.rounds and a.weighted_sum_rate == b.weighted_sum_rate
        )
    # recorded, not asserted: a ULP tie-flip must not abort the perf-record
    # write — bit equality is pinned by tests/test_scheduling_edges.py
    emit("sched.backend_equality_M48", 0.0,
         "identical" if identical else "DIVERGED (see test suite)")
    return {"suite": "scheduling", "fast": fast,
            "backends_identical_M48": identical, "records": records}


def main(fast: bool = False):
    # literal vs lazy at small M (identical outputs; timing gap)
    gains, w = _instance(8, 3)
    us_lit = timeit(lambda: scheduling.literal_graph_schedule(
        gains, w, 2, noise_power=NOISE), repeats=3)
    us_lazy = timeit(lambda: scheduling.lazy_greedy_schedule(
        gains, w, 2, noise_power=NOISE), repeats=3)
    emit("sched.literal_M8", us_lit, "explicit C(M,K)*T graph")
    emit("sched.lazy_M8", us_lazy, f"speedup {us_lit / us_lazy:.1f}x")

    # optimality gap vs brute force
    gaps = []
    for seed in range(5):
        g2, w2 = _instance(6, 2, seed)
        greedy = scheduling.lazy_greedy_schedule(g2, w2, 2, noise_power=NOISE)
        best = scheduling.brute_force_schedule(g2, w2, 2, noise_power=NOISE)
        gaps.append(greedy.weighted_sum_rate / best.weighted_sum_rate)
    emit("sched.greedy_vs_optimal", 0.0, f"ratio {np.mean(gaps):.3f}")

    # batched rate engine vs the seed's per-subset loop (the PR's hot path)
    _candidate_scoring(fast)

    # paper scale: M=300, K=3, T=35 (infeasible for the literal graph:
    # C(300,3)*35 = 1.55e8 vertices)
    m, t = (100, 10) if fast else (300, 35)
    gains, w = _instance(m, t)
    t0 = time.perf_counter()
    s = scheduling.lazy_greedy_schedule(gains, w, 3, noise_power=NOISE)
    us = (time.perf_counter() - t0) * 1e6
    emit(f"sched.lazy_M{m}_T{t}", us,
         f"wsum {s.weighted_sum_rate:.3f} literal_would_need "
         f"{35 * 4455100 if not fast else 10 * 161700} vertices")
    s.validate(m, 3)

    # larger candidate pools, reachable now that scoring is batched (the
    # seed's Python loop capped practical pools at ~16)
    for pool in (16, 48):
        t0 = time.perf_counter()
        sp = scheduling.lazy_greedy_schedule(
            gains, w, 3, noise_power=NOISE, candidate_pool=pool
        )
        us = (time.perf_counter() - t0) * 1e6
        emit(f"sched.lazy_M{m}_pool{pool}", us,
             f"wsum {sp.weighted_sum_rate:.3f}")

    # numpy vs jax device-resident greedy; records land in
    # BENCH_scheduling.json via benchmarks/run.py
    return backend_sweep(fast)


if __name__ == "__main__":
    main()
