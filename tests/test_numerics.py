"""Numerical-algorithm oracles: chunked attention and the SSD scan are
validated against naive reference implementations across shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded numpy-backed shim
    from _propcheck import given, settings, strategies as st

from repro.models import layers as L
from repro.models import mamba2 as M


# --------------------------------------------------------------------------
# chunked (online-softmax) attention vs naive softmax
# --------------------------------------------------------------------------

def naive_attention(q, k, v, mask):
    """Full (S, S) softmax reference. q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(d) + jnp.where(mask, 0.0, -jnp.inf)[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)


@pytest.mark.parametrize("chunk", [3, 8, 64])
@pytest.mark.parametrize("spec", [
    L.AttnMaskSpec(causal=True),
    L.AttnMaskSpec(causal=True, window=5),
    L.AttnMaskSpec(causal=True, block_local=8),
    L.AttnMaskSpec(causal=False),
])
def test_chunked_attention_matches_naive(chunk, spec):
    key = jax.random.PRNGKey(0)
    b, sq, sk, h, hkv, d = 2, 17, 17, 4, 2, 8
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, hkv, d))
    got = L.chunked_attention(q, k, v, mask_spec=spec, kv_chunk=chunk)
    mask = L._mask_block(jnp.arange(sq), jnp.arange(sk), spec)
    want = naive_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=2e-2, rtol=2e-2)  # bf16 compute


def test_chunked_attention_q_offset_decode():
    """Decode semantics: q at position `off` over a cache of valid length."""
    key = jax.random.PRNGKey(3)
    b, h, hkv, d, smax = 1, 2, 2, 8, 32
    off = 11
    q = jax.random.normal(key, (b, 1, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, smax, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, smax, hkv, d))
    got = L.chunked_attention(
        q, k, v, mask_spec=L.AttnMaskSpec(causal=True), q_offset=off,
        kv_chunk=8, kv_valid_len=jnp.asarray(off + 1, jnp.int32))
    # oracle: attend over exactly the first off+1 keys
    want = naive_attention(q, k[:, : off + 1], v[:, : off + 1],
                           jnp.ones((1, off + 1), bool))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=2e-2, rtol=2e-2)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 40), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_chunked_attention_chunk_invariance(sk, chunk, seed):
    """Output must not depend on the chunking factor."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 5, 2, 4))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, sk, 2, 4))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, sk, 2, 4))
    spec = L.AttnMaskSpec(causal=False)
    a = L.chunked_attention(q, k, v, mask_spec=spec, kv_chunk=chunk)
    b_ = L.chunked_attention(q, k, v, mask_spec=spec, kv_chunk=max(sk, 1))
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b_, np.float32), atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------------------
# SSD chunked scan vs naive recurrence
# --------------------------------------------------------------------------

def naive_ssd(x, dt, a_log, b, c, init=None):
    """Sequential SSM recurrence oracle (fp64-ish via fp32 step loop)."""
    bsz, s, h, p_ = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    a = -np.exp(np.asarray(a_log, np.float64))
    xn = np.asarray(x, np.float64)
    dtn = np.asarray(dt, np.float64)
    bn = np.repeat(np.asarray(b, np.float64), rep, axis=2)
    cn = np.repeat(np.asarray(c, np.float64), rep, axis=2)
    hst = np.zeros((bsz, h, p_, n)) if init is None else np.asarray(init, np.float64)
    ys = []
    for t in range(s):
        dec = np.exp(dtn[:, t] * a[None, :])                       # (B,H)
        xd = xn[:, t] * dtn[:, t][..., None]                       # (B,H,P)
        hst = hst * dec[..., None, None] + xd[..., None] * bn[:, t][:, :, None, :]
        ys.append(np.einsum("bhpn,bhn->bhp", hst, cn[:, t]))
    return np.stack(ys, axis=1), hst


@pytest.mark.parametrize("s,chunk", [(8, 4), (12, 4), (16, 8), (7, 7)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    key = jax.random.PRNGKey(0)
    bsz, h, p_, g, n = 2, 4, 4, 2, 3
    x = jax.random.normal(key, (bsz, s, h, p_)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (bsz, s, h)))
    a_log = jnp.log(jax.random.uniform(jax.random.fold_in(key, 2), (h,), minval=1.0, maxval=4.0))
    b = jax.random.normal(jax.random.fold_in(key, 3), (bsz, s, g, n)) * 0.5
    c = jax.random.normal(jax.random.fold_in(key, 4), (bsz, s, g, n)) * 0.5
    if s % chunk:
        pytest.skip("chunk must divide s for the raw scan")
    y, final = M.ssd_chunked(x, dt, a_log, b, c, chunk=chunk)
    y_ref, final_ref = naive_ssd(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref, atol=1e-4, rtol=1e-4)


def test_ssd_init_state_continuation():
    """Chunk-wise prefill: running two halves with carried state == one run."""
    key = jax.random.PRNGKey(7)
    bsz, s, h, p_, g, n = 1, 16, 2, 4, 1, 3
    x = jax.random.normal(key, (bsz, s, h, p_)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (bsz, s, h)))
    a_log = jnp.log(jax.random.uniform(jax.random.fold_in(key, 2), (h,), minval=1.0, maxval=4.0))
    b = jax.random.normal(jax.random.fold_in(key, 3), (bsz, s, g, n)) * 0.5
    c = jax.random.normal(jax.random.fold_in(key, 4), (bsz, s, g, n)) * 0.5
    y_full, st_full = M.ssd_chunked(x, dt, a_log, b, c, chunk=4)
    y1, st1 = M.ssd_chunked(x[:, :8], dt[:, :8], a_log, b[:, :8], c[:, :8], chunk=4)
    y2, st2 = M.ssd_chunked(x[:, 8:], dt[:, 8:], a_log, b[:, 8:], c[:, 8:],
                            chunk=4, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4, rtol=1e-4)


def test_ssd_step_matches_scan():
    """O(1) decode recurrence == one more step of the chunked scan."""
    key = jax.random.PRNGKey(9)
    bsz, h, p_, g, n = 2, 2, 4, 1, 3
    st = jax.random.normal(key, (bsz, h, p_, n))
    x_t = jax.random.normal(jax.random.fold_in(key, 1), (bsz, h, p_))
    dt_t = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (bsz, h)))
    a_log = jnp.log(jax.random.uniform(jax.random.fold_in(key, 3), (h,), minval=1.0, maxval=4.0))
    b_t = jax.random.normal(jax.random.fold_in(key, 4), (bsz, g, n))
    c_t = jax.random.normal(jax.random.fold_in(key, 5), (bsz, g, n))
    y, new = M.ssd_step(st, x_t, dt_t, a_log, b_t, c_t)
    y_ref, new_ref = naive_ssd(x_t[:, None], dt_t[:, None], a_log,
                               b_t[:, None], c_t[:, None], init=st)
    np.testing.assert_allclose(np.asarray(y), y_ref[:, 0], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(new), new_ref, atol=1e-4, rtol=1e-4)


def test_ssd_bf16_close_to_f32():
    """The ssm_bf16 lever (§Perf pair A iteration 6) stays within bf16 noise."""
    key = jax.random.PRNGKey(11)
    bsz, s, h, p_, g, n = 1, 32, 2, 8, 1, 4
    x = jax.random.normal(key, (bsz, s, h, p_)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (bsz, s, h)))
    a_log = jnp.log(jax.random.uniform(jax.random.fold_in(key, 2), (h,), minval=1.0, maxval=4.0))
    b = jax.random.normal(jax.random.fold_in(key, 3), (bsz, s, g, n)) * 0.5
    c = jax.random.normal(jax.random.fold_in(key, 4), (bsz, s, g, n)) * 0.5
    y32, _ = M.ssd_chunked(x, dt, a_log, b, c, chunk=8)
    y16, _ = M.ssd_chunked(x, dt, a_log, b, c, chunk=8,
                           einsum_dtype=jnp.bfloat16)
    rel = float(jnp.max(jnp.abs(y16 - y32)) / (jnp.max(jnp.abs(y32)) + 1e-9))
    assert rel < 0.03
