"""Wireless channel model (paper §II-A).

Channel gain of device k at round t:  h_k^t = L_k^t * h0^t
  - L_k^t : large-scale free-space path loss
        L = sqrt(delta * lambda^2) / (4*pi*d^(alpha/2))
    (the paper writes L = sqrt(delta)*lambda / (4 pi d^{alpha/2}); delta is the
    combined antenna gain, lambda the carrier wavelength, d the PS distance,
    alpha the path-loss exponent).
  - h0^t : small-scale Rayleigh fading, h0 ~ CN(0, 1).

All quantities are vectorized over devices and generated with explicit JAX PRNG
keys so every simulation is reproducible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Speed of light (m/s).
_C = 299_792_458.0


@dataclasses.dataclass(frozen=True)
class CellConfig:
    """Static description of the cell (paper §IV settings by default)."""

    num_devices: int = 300          # M
    cell_radius_m: float = 500.0    # PS cell size
    min_distance_m: float = 10.0    # keep devices out of the antenna near field
    carrier_hz: float = 2.4e9       # typical ISM carrier (paper does not state one)
    path_loss_exp: float = 3.0      # alpha
    antenna_gain: float = 1.0       # delta (unit gain)
    bandwidth_hz: float = 4e6       # uplink bandwidth B
    noise_dbm_per_hz: float = -174.0
    max_power_w: float = 0.01       # p^max
    slot_seconds: float = 0.2       # uplink slot t
    downlink_bandwidth_hz: float = 10e6
    downlink_power_w: float = 0.2

    @property
    def wavelength_m(self) -> float:
        return _C / self.carrier_hz

    @property
    def noise_power_w(self) -> float:
        """Total noise power over the uplink band: sigma^2 = N0 * B (watts)."""
        n0_w_per_hz = 10.0 ** (self.noise_dbm_per_hz / 10.0) * 1e-3
        return n0_w_per_hz * self.bandwidth_hz


def sample_positions(key: jax.Array, cfg: CellConfig) -> jax.Array:
    """Uniformly distribute devices in the cell disk. Returns distances (M,)."""
    k1, _ = jax.random.split(key)
    # Uniform over the disk => CDF(r) = r^2 / R^2 => r = R * sqrt(u).
    u = jax.random.uniform(k1, (cfg.num_devices,))
    r = cfg.cell_radius_m * jnp.sqrt(u)
    return jnp.maximum(r, cfg.min_distance_m)


def large_scale_gain(distances_m: jax.Array, cfg: CellConfig) -> jax.Array:
    """Free-space path-loss amplitude gain L_k (linear, amplitude domain)."""
    num = jnp.sqrt(cfg.antenna_gain) * cfg.wavelength_m
    den = 4.0 * jnp.pi * distances_m ** (cfg.path_loss_exp / 2.0)
    return num / den


def sample_small_scale(key: jax.Array, shape) -> jax.Array:
    """|h0| with h0 ~ CN(0,1) (Rayleigh magnitude, E[|h0|^2] = 1)."""
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, shape) * jnp.sqrt(0.5)
    im = jax.random.normal(ki, shape) * jnp.sqrt(0.5)
    return jnp.sqrt(re**2 + im**2)


def sample_channel_gains(
    key: jax.Array, distances_m: jax.Array, cfg: CellConfig
) -> jax.Array:
    """Per-device amplitude channel gain h_k = L_k * |h0| for one round."""
    ls = large_scale_gain(distances_m, cfg)
    ss = sample_small_scale(key, distances_m.shape)
    return ls * ss


def sample_round_channels(
    key: jax.Array, distances_m: jax.Array, cfg: CellConfig, num_rounds: int
) -> jax.Array:
    """Channel gains for every round: (T, M). Block fading across rounds."""
    keys = jax.random.split(key, num_rounds)
    return jax.vmap(sample_channel_gains, in_axes=(0, None, None))(
        keys, distances_m, cfg
    )


def downlink_time_seconds(
    model_bits: float, gains: jax.Array, cfg: CellConfig
) -> float:
    """Broadcast time T_d = max_k I / (B_d log2(1 + p_d * gamma_k)) (paper §IV).

    gamma_k is the received downlink SNR at device k.  Computed in float64
    like the uplink rate engine: squaring a far device's gain under a high
    ``path_loss_exp`` underflows float32, and ``log1p`` keeps the rate
    nonzero for SNRs below the 1 + x rounding threshold — either failure
    used to return ``inf`` and silently poison the Fig. 5 time axis.  A
    genuinely unreachable device (zero gain) raises instead.
    """
    n0_w_per_hz = 10.0 ** (cfg.noise_dbm_per_hz / 10.0) * 1e-3
    noise = n0_w_per_hz * cfg.downlink_bandwidth_hz
    g = np.asarray(gains, np.float64)
    snr = cfg.downlink_power_w * g * g / noise
    if not np.all(np.isfinite(snr)):
        raise ValueError(
            "non-finite downlink SNR: some channel gain is NaN/inf; check "
            "the upstream gain computation"
        )
    if not np.all(snr > 0.0):
        raise ValueError(
            "zero downlink SNR: some device has zero channel gain, so the "
            "broadcast never completes (T_d = inf); check the cell geometry"
        )
    rate = cfg.downlink_bandwidth_hz * np.log1p(snr) / np.log(2.0)
    return float(np.max(model_bits / rate))
