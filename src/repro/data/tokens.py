"""Synthetic token pipeline for the LLM-scale trainer.

Generates reproducible pseudo-text token streams with a power-law unigram
distribution plus a short-range bigram structure, so perplexity decreases
measurably during smoke training (pure-uniform tokens would give a flat
loss and hide wiring bugs).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipfian unigram over a capped support for cheap sampling.
        support = min(v, 4096)
        ranks = np.arange(1, support + 1)
        probs = 1.0 / ranks**1.1
        self._support = support
        self._probs = probs / probs.sum()
        # Deterministic "grammar": each token prefers a successor band.
        self._succ = rng.integers(0, support, size=support)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        base = rng.choice(self._support, size=(batch, seq), p=self._probs)
        # 50% of positions follow the bigram successor of the previous token.
        follow = rng.random((batch, seq)) < 0.5
        out = base.copy()
        out[:, 1:] = np.where(
            follow[:, 1:], self._succ[out[:, :-1]], base[:, 1:]
        )
        return out.astype(np.int32)


def synthetic_token_batches(
    vocab_size: int, batch: int, seq: int, *, seed: int = 0
):
    """Infinite iterator of (tokens, labels) next-token-prediction batches."""
    stream = TokenStream(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = stream.sample(rng, batch, seq + 1)
        yield toks[:, :-1], toks[:, 1:]


@dataclasses.dataclass
class TokenDataset:
    """A fixed token corpus shaped like the FL drivers' image Dataset.

    x_* are (N, S) int32 token rows, y_* the (N, S) shifted next-token
    labels; class_* are (N,) pseudo-class ids (first token mod 10) so
    :func:`repro.data.partition.dirichlet_partition` — which partitions by
    class label — produces the same style of non-iid shards over token
    rows as over MNIST-like images.
    """

    x_train: np.ndarray   # (N, S) int32
    y_train: np.ndarray   # (N, S) int32
    x_test: np.ndarray
    y_test: np.ndarray
    class_train: np.ndarray   # (N,) int32 pseudo-class for partitioning
    class_test: np.ndarray


def make_token_dataset(
    *,
    vocab_size: int,
    num_samples: int = 2_000,
    seq_len: int = 16,
    train_frac: float = 0.9,
    seed: int = 0,
) -> TokenDataset:
    """Sample a fixed (N, S) next-token corpus from :class:`TokenStream`.

    Each row is an independent length-(S+1) draw split into (tokens,
    labels) — the FL analogue of one image sample, so the client banks,
    Dirichlet partitioner, and eval plans operate on token rows exactly as
    they do on image rows.
    """
    stream = TokenStream(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    toks = stream.sample(rng, num_samples, seq_len + 1)
    x, y = toks[:, :-1], toks[:, 1:]
    classes = (x[:, 0] % 10).astype(np.int32)
    n_train = int(train_frac * num_samples)
    return TokenDataset(
        x_train=x[:n_train], y_train=y[:n_train],
        x_test=x[n_train:], y_test=y[n_train:],
        class_train=classes[:n_train], class_test=classes[n_train:],
    )
