"""Recompile & NaN sanitizer regression tests (tools/flcheck/sanitizers).

The compile-count guard pins the repo's central trace-safety invariant:
every FL driver compiles a *constant* number of XLA programs no matter
how the horizon scales — ``run_federated_learning`` (scan) across round
counts, ``run_horizon_vmapped`` across seed counts, and the per-round
batched engine across round counts.  A per-round or per-seed retrace
(the PR 7 ``jax.jit(bound_method)`` class of bug) shows up as a count
that grows with the sweep, which these tests turn into a hard failure.

Counting protocol: XLA backend-compile counts are process-wide, so each
test warms up first — one run at a *different* horizon size (caches every
shape-independent program), plus the per-size ``jax.random.split`` setup
programs (an O(1)-per-size cost that would otherwise alias: ``split(key,
2)`` shares its program with the ubiquitous 2-way ``split(key)``).  The
counted runs then compile exactly the size-specific driver programs,
whose number must match.

Because the cache is process-wide, every *counted* size here must be
unique across the whole tier-1 suite: a different test file running the
same horizon length caches that size's small ``(T,)``-shaped programs and
skews one side of the comparison (T=2 once measured 1 vs 17 for T=8 in a
full-suite run — 14 other call sites use ``num_rounds=2``).  Counted
sizes: rounds 6/11 (scan), 5/9 (per-round), 7/12 (online scan,
tests/test_policy_scan.py), seed-sweep widths 1/4 — keep them unused
elsewhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like
from tools.flcheck.sanitizers import compile_count, nan_guard

M = 6


@pytest.fixture(scope="module")
def world():
    ds = make_mnist_like(num_samples=300, seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.y_train, M, seed=0)
    return ds, cell, shards


def _cfg(rounds, *, horizon="scan", seed=0):
    return FLConfig(num_devices=M, group_size=2, num_rounds=rounds,
                    scheduler="lazy-gwmin", power_mode="max",
                    compression="adaptive", fl_engine="batched",
                    horizon=horizon, seed=seed)


def _warm_key_splits(*sizes):
    key = jax.random.PRNGKey(0)
    for n in sizes:
        jax.random.split(key, n)


# --------------------------------------------------------------------------
# driver compile counts: constant across horizon scaling
# --------------------------------------------------------------------------

def test_scan_compile_count_constant_in_rounds(world):
    ds, cell, shards = world
    fl.run_federated_learning(ds, shards, cell, _cfg(3))   # warm T=3
    _warm_key_splits(6, 11)
    counts = {}
    for t_rounds in (6, 11):
        with compile_count() as tally:
            fl.run_federated_learning(ds, shards, cell, _cfg(t_rounds))
        counts[t_rounds] = tally.count
    assert counts[6] == counts[11], (
        f"scan driver compile count scales with rounds: {counts}"
    )
    assert counts[6] > 0   # each T is a fresh static shape: must compile

    with compile_count() as tally:
        fl.run_federated_learning(ds, shards, cell, _cfg(6))
    assert tally.count == 0, "identical rerun must be fully cached"


def test_vmapped_compile_count_constant_in_seeds(world):
    ds, cell, shards = world
    cfg = _cfg(2)
    fl.run_horizon_vmapped(ds, shards, cell, cfg, seeds=range(2))  # warm S=2
    counts = {}
    for s in (1, 4):
        with compile_count() as tally:
            fl.run_horizon_vmapped(ds, shards, cell, cfg, seeds=range(s))
        counts[s] = tally.count
    assert counts[1] == counts[4], (
        f"vmapped driver compile count scales with seeds: {counts}"
    )
    assert counts[4] > 0

    with compile_count() as tally:
        fl.run_horizon_vmapped(ds, shards, cell, cfg, seeds=range(4))
    assert tally.count == 0, "identical rerun must be fully cached"


@pytest.fixture(scope="module")
def equal_world():
    """Equal-size shards: the batched engine jits ``_round_step`` with the
    group's batch count ``nb`` static, so under non-iid Dirichlet shards
    the program count tracks which nb values the *schedule* happens to
    draw — content, not round count.  Equal shards collapse nb to one
    static value, isolating the invariant this test pins (no per-round
    retrace)."""
    ds = make_mnist_like(num_samples=300, seed=0)
    cell = channel.CellConfig(num_devices=M)
    per = len(ds.y_train) // M
    shards = [np.arange(i * per, (i + 1) * per) for i in range(M)]
    return ds, cell, shards


def test_batched_engine_compile_count_constant_in_rounds(equal_world):
    ds, cell, shards = equal_world
    fl.run_federated_learning(ds, shards, cell,
                              _cfg(3, horizon="per-round"))   # warm T=3
    _warm_key_splits(5, 9)
    counts = {}
    for t_rounds in (5, 9):
        with compile_count() as tally:
            fl.run_federated_learning(ds, shards, cell,
                                      _cfg(t_rounds, horizon="per-round"))
        counts[t_rounds] = tally.count
    assert counts[5] == counts[9], (
        f"per-round batched engine compile count scales with rounds: {counts}"
    )


# --------------------------------------------------------------------------
# the PR 7 bound-method recompile, pinned as a live repro (FLC001's bug)
# --------------------------------------------------------------------------

class _Model:
    def accuracy(self, params, x):
        return jnp.mean(params * x)


def _accuracy(params, x):
    return jnp.mean(params * x)


_jit_accuracy = jax.jit(_accuracy)   # the fix: module-level, stable identity


def test_bound_method_jit_recompiles_per_call():
    m = _Model()
    p, x = jnp.ones(16), jnp.ones(16)
    jax.jit(m.accuracy)(p, x).block_until_ready()  # flcheck: disable=FLC001
    with compile_count() as bad:
        for _ in range(3):
            fn = jax.jit(m.accuracy)   # flcheck: disable=FLC001
            fn(p, x).block_until_ready()
    # each call wraps a fresh bound-method object: the jit cache misses
    # every time (this is the 2.2x PR 7 slowdown, kept as a live repro)
    assert bad.count >= 3, f"expected a compile per call, got {bad.count}"

    _jit_accuracy(p, x).block_until_ready()
    with compile_count() as good:
        for _ in range(3):
            _jit_accuracy(p, x).block_until_ready()
    assert good.count == 0, "module-level jit must hit its cache"


# --------------------------------------------------------------------------
# NaN sanitizer
# --------------------------------------------------------------------------

def test_nan_guard_raises_at_source_and_restores():
    prev = jax.config.jax_debug_nans
    with pytest.raises(FloatingPointError):
        with nan_guard():
            jnp.divide(jnp.zeros(()), jnp.zeros(())).block_until_ready()
    assert jax.config.jax_debug_nans == prev


def test_nan_guard_restores_on_clean_exit():
    prev = jax.config.jax_debug_nans
    with nan_guard():
        assert jax.config.jax_debug_nans is True
        jnp.ones(4).block_until_ready()
    assert jax.config.jax_debug_nans == prev


def test_nan_guard_disabled_passes_nans_through():
    with nan_guard(enable=False):
        out = jnp.divide(jnp.zeros(()), jnp.zeros(()))
    assert np.isnan(np.asarray(out))
