"""End-to-end behaviour tests for the paper's system (FL over NOMA)."""
import jax
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like

M = 30  # small cell for test speed


@pytest.fixture(scope="module")
def small_world():
    ds = make_mnist_like(num_samples=2500, seed=0)
    cell = channel.CellConfig(num_devices=M)
    shards = dirichlet_partition(ds.y_train, M, seed=0)
    return ds, cell, shards


def _run(ds, cell, shards, *, rounds=8, scheduler="lazy-gwmin",
         power="max", uplink="noma", compression="adaptive", seed=0):
    cfg = FLConfig(num_devices=M, group_size=3, num_rounds=rounds,
                   scheduler=scheduler, power_mode=power,
                   compression=compression, seed=seed)
    return fl.run_federated_learning(ds, shards, cell, cfg, uplink=uplink)


def test_fl_accuracy_improves(small_world):
    ds, cell, shards = small_world
    res = _run(ds, cell, shards, rounds=10)
    accs = res.accuracies()
    assert accs[-1] > 0.3, f"final accuracy too low: {accs[-1]}"
    assert accs[-1] > accs[0]


def test_constraint_c1_each_device_once(small_world):
    ds, cell, shards = small_world
    res = _run(ds, cell, shards, rounds=8)
    seen = [d for log in res.logs for d in log.devices]
    assert len(seen) == len(set(seen))


def test_noma_rounds_faster_than_tdma(small_world):
    """Paper §IV: NOMA round = t + T_d, TDMA round = K*t + T_d."""
    ds, cell, shards = small_world
    noma_res = _run(ds, cell, shards, rounds=4, uplink="noma")
    tdma_res = _run(ds, cell, shards, rounds=4, uplink="tdma")
    t_noma = np.diff(noma_res.times())
    t_tdma = np.diff(tdma_res.times())
    # identical downlink; uplink is K x longer for TDMA
    np.testing.assert_allclose(
        t_tdma - t_noma, (3 - 1) * cell.slot_seconds, rtol=1e-6)


def test_adaptive_bits_recorded_and_bounded(small_world):
    ds, cell, shards = small_world
    res = _run(ds, cell, shards, rounds=5)
    for log in res.logs:
        assert np.all(log.bits >= 1) and np.all(log.bits <= 32)
        assert np.all(log.compression_ratios >= 1.0)


def test_tdma_adaptive_compression_uses_subslot_budget(small_world):
    """Regression: adaptive compression used to be silently skipped for TDMA
    (uploads forced to 32 bits), biasing the NOMA-vs-TDMA comparison.  Each
    TDMA device now quantizes to its own interference-free sub-slot budget."""
    ds, cell, shards = small_world
    res = _run(ds, cell, shards, rounds=3, uplink="tdma")
    bits = np.concatenate([log.bits for log in res.logs])
    assert np.all((bits >= 1) & (bits <= 32))
    assert np.any(bits < 32), "TDMA budgets here are compressive; 32 = skipped"
    ratios = np.concatenate([log.compression_ratios for log in res.logs])
    assert np.all(ratios >= 1.0)


def test_tdma_compression_none_stays_full_precision(small_world):
    ds, cell, shards = small_world
    res = _run(ds, cell, shards, rounds=2, uplink="tdma", compression="none")
    for log in res.logs:
        assert np.all(log.bits == 32)


def test_deterministic_given_seed(small_world):
    ds, cell, shards = small_world
    r1 = _run(ds, cell, shards, rounds=3, seed=5)
    r2 = _run(ds, cell, shards, rounds=3, seed=5)
    np.testing.assert_array_equal(r1.accuracies(), r2.accuracies())
    assert [l.devices for l in r1.logs] == [l.devices for l in r2.logs]


@pytest.fixture(scope="module")
def tiny_world():
    """4-device cell so a 3-round, K=2 horizon exhausts the device set."""
    ds = make_mnist_like(num_samples=400, seed=0)
    cell = channel.CellConfig(num_devices=4)
    shards = dirichlet_partition(ds.y_train, 4, seed=0)
    return ds, cell, shards


@pytest.mark.parametrize("uplink", ["noma", "tdma"])
@pytest.mark.parametrize("scheduler", ["round-robin", "proportional-fair"])
def test_fl_survives_empty_tail_rounds(tiny_world, uplink, scheduler):
    """Regression: T*K > M schedules produce empty tail groups; aggregation
    used to crash (``tree_map`` over zero deltas).  Empty rounds must skip
    training/aggregation but still advance the wall clock and be logged."""
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   scheduler=scheduler, power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg, uplink=uplink)
    assert len(res.logs) == 3
    assert res.logs[-1].devices == ()
    assert res.logs[-1].bits.size == 0 and res.logs[-1].rates.size == 0
    times = res.times()
    assert np.all(np.diff(times) > 0), "empty rounds must still take time"
    assert np.isfinite(res.logs[-1].test_accuracy)
    # the empty round leaves the model untouched: same accuracy as round 1
    assert res.logs[-1].test_accuracy == res.logs[-2].test_accuracy


def test_final_round_eval_fresh_when_eval_every_skips_it(tiny_world):
    """Regression: with eval_every > 1 and num_rounds - 1 not a multiple,
    the final round used to copy the last (stale) eval instead of measuring
    the final model — FLResult.accuracies()[-1] lied about the run's
    outcome.  The last round must always be evaluated."""
    ds, cell, shards = tiny_world
    from repro.models import lenet

    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=4,
                   scheduler="age-fair", power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg, eval_every=2)
    fresh = float(lenet.accuracy(
        res.final_params, np.asarray(ds.x_test), np.asarray(ds.y_test)))
    assert res.logs[-1].test_accuracy == fresh
    # intermediate skipped rounds still carry the previous eval forward
    assert res.logs[1].test_accuracy == res.logs[0].test_accuracy


def test_tdma_empty_tail_round_charges_no_uplink_airtime(tiny_world):
    """Regression: an empty T*K > M tail round under TDMA used to charge
    group_size * slot_seconds of uplink airtime with zero transmitting
    devices, skewing the Fig. 5 time axis.  Airtime is len(devs) sub-slots."""
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   scheduler="round-robin", power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg, uplink="tdma")
    assert res.logs[-1].devices == ()
    per_round = np.diff(np.concatenate([[0.0], res.times()]))
    # full rounds: 2 sub-slots + downlink; empty tail: downlink only
    np.testing.assert_allclose(
        per_round[-1], per_round[0] - 2 * cell.slot_seconds, rtol=1e-9)


def test_noma_empty_tail_round_charges_no_uplink_airtime(tiny_world):
    """The shared NOMA uplink slot is only spent when someone transmits: an
    empty tail round costs the downlink broadcast only (keeping the NOMA
    and TDMA time axes consistent on empty rounds)."""
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   scheduler="round-robin", power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg, uplink="noma")
    assert res.logs[-1].devices == ()
    per_round = np.diff(np.concatenate([[0.0], res.times()]))
    np.testing.assert_allclose(
        per_round[-1], per_round[0] - cell.slot_seconds, rtol=1e-9)


def test_tdma_partial_tail_round_charges_len_devs_subslots(tiny_world):
    """A partial tail group (1 of K=3 devices left) is charged 1 sub-slot,
    not K."""
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=3, num_rounds=2,
                   scheduler="round-robin", power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg, uplink="tdma")
    assert len(res.logs[0].devices) == 3 and len(res.logs[1].devices) == 1
    per_round = np.diff(np.concatenate([[0.0], res.times()]))
    np.testing.assert_allclose(
        per_round[1], per_round[0] - 2 * cell.slot_seconds, rtol=1e-9)


def test_scheduler_weighted_rate_ordering(small_world):
    """Greedy MWIS schedule achieves >= weighted sum rate of random/RR."""
    ds, cell, shards = small_world
    from repro.core import scheduling

    sizes = np.array([len(s) for s in shards], float)
    weights = sizes / sizes.sum()
    key = jax.random.PRNGKey(0)
    dist = channel.sample_positions(key, cell)
    gains = np.asarray(channel.sample_round_channels(
        jax.random.fold_in(key, 2), dist, cell, 5))
    g = scheduling.lazy_greedy_schedule(
        gains, weights, 3, pmax=cell.max_power_w,
        noise_power=cell.noise_power_w)
    r = scheduling.random_schedule(
        np.random.default_rng(0), gains, weights, 3,
        pmax=cell.max_power_w, noise_power=cell.noise_power_w)
    rr = scheduling.round_robin_schedule(
        gains, weights, 3, pmax=cell.max_power_w,
        noise_power=cell.noise_power_w)
    assert g.weighted_sum_rate >= r.weighted_sum_rate
    assert g.weighted_sum_rate >= rr.weighted_sum_rate


@pytest.mark.parametrize("scheduler", ["update-aware", "age-fair"])
def test_online_policies_run_live_and_revisit(tiny_world, scheduler):
    """Online policies select inside the training loop: with T*K > M they
    revisit devices instead of emitting empty tail rounds, and the whole
    run stays deterministic given the seed."""
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   scheduler=scheduler, power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg)
    assert len(res.logs) == 3
    assert all(len(log.devices) == 2 for log in res.logs)
    seen = [d for log in res.logs for d in log.devices]
    assert len(seen) == 6
    assert len(seen) > len(set(seen))                # some device revisited
    assert all(0 <= d < 4 for d in seen)
    assert np.isfinite(res.logs[-1].test_accuracy)
    for log in res.logs:                              # live rounds upload
        assert np.all(log.bits >= 1) and np.all(log.bits <= 32)
    r2 = fl.run_federated_learning(ds, shards, cell, cfg)
    assert [l.devices for l in res.logs] == [l.devices for l in r2.logs]
    np.testing.assert_array_equal(res.accuracies(), r2.accuracies())


def test_online_policy_live_tdma_uplink(tiny_world):
    ds, cell, shards = tiny_world
    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=2,
                   scheduler="age-fair", power_mode="max",
                   compression="adaptive", seed=0)
    res = fl.run_federated_learning(ds, shards, cell, cfg, uplink="tdma")
    assert len(res.logs) == 2
    assert all(len(log.devices) == 2 for log in res.logs)


def test_precomputed_policies_unchanged_by_registry_path(small_world):
    """fl.make_schedule now resolves through the registry; the precomputed
    path must keep producing the same schedules the FL loop consumed before
    the redesign (spot-check: same devices for the same seed/config)."""
    ds, cell, shards = small_world
    from repro.core import scheduling

    cfg = FLConfig(num_devices=M, group_size=3, num_rounds=4,
                   scheduler="random", power_mode="max",
                   compression="adaptive", seed=5)
    key = jax.random.PRNGKey(cfg.seed)
    dist = channel.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(channel.sample_round_channels(
        jax.random.fold_in(key, 2), dist, cell, cfg.num_rounds))
    sizes = np.array([len(s) for s in shards], float)
    weights = sizes / sizes.sum()
    via_registry = fl.make_schedule(gains, weights, cell, cfg)
    direct = scheduling.random_schedule(
        np.random.default_rng(cfg.seed + 17), gains, weights, 3,
        power_mode="max", pmax=cell.max_power_w,
        noise_power=cell.noise_power_w)
    assert via_registry.rounds == direct.rounds
    for pa, pb in zip(via_registry.powers, direct.powers):
        np.testing.assert_array_equal(pa, pb)


def test_caller_supplied_online_schedule_accepted(tiny_world):
    """Regression: a Schedule built offline from an online policy revisits
    devices; run_federated_learning must honor the schedule's own
    allow_revisits flag (set by build_schedule) instead of crashing on C1."""
    ds, cell, shards = tiny_world
    from repro.core import scheduling

    cfg = FLConfig(num_devices=4, group_size=2, num_rounds=3,
                   scheduler="age-fair", power_mode="max",
                   compression="adaptive", seed=0)
    key = jax.random.PRNGKey(cfg.seed)
    dist = channel.sample_positions(jax.random.fold_in(key, 1), cell)
    gains = np.asarray(channel.sample_round_channels(
        jax.random.fold_in(key, 2), dist, cell, cfg.num_rounds))
    sizes = np.array([len(s) for s in shards], float)
    weights = sizes / sizes.sum()
    sched = scheduling.build_schedule(
        scheduling.get_policy("age-fair"), gains, weights,
        fl.policy_config(cell, cfg))
    assert sum(len(g) for g in sched.rounds) > 4     # revisits present
    res = fl.run_federated_learning(ds, shards, cell, cfg, schedule=sched)
    assert [l.devices for l in res.logs] == [tuple(g) for g in sched.rounds]
