"""flcheck: this repo's trace-safety & determinism invariants as lint rules.

Stdlib-``ast`` only (the offline CI container must run it with no extra
wheels, and it must never import the code it checks).  Every rule is named,
individually suppressible (``# flcheck: disable=FLC001`` on any line the
flagged node spans), and grounded in a bug this repo actually shipped:

  FLC001  ``jax.jit`` / ``jax.vmap`` / ``jax.pmap`` applied to a bound
          method or a local lambda at call time.  Each call builds a fresh
          function object, so the jit cache misses every time — the PR 7
          ``jax.jit(model.accuracy)`` bug (2.2x on the cells legacy sweep).
  FLC002  builtin ``hash()`` / ``id()``.  String hashing is salted per
          process (PYTHONHASHSEED) and ``id()`` is an address — seeds, PRNG
          folds and registry/init paths derived from either differ across
          processes — the PR 8 model-init bug (fixed with ``zlib.crc32``).
  FLC003  host-sync constructs (``float()`` / ``int()`` / ``bool()`` /
          ``.item()`` / ``np.asarray``) applied to traced values inside
          functions reachable from a ``@jit`` / ``lax.scan`` /
          ``lax.while_loop`` body (a lightweight call graph decides
          reachability).
  FLC004  Python int arithmetic crossing the ``jnp`` boundary without an
          explicit dtype — host ints above 2**31 - 1 silently overflow the
          default int32 (the PR 7 10^8-param payload-accounting bug).
  FLC005  ``log(1 + x)`` / ``1 - exp(x)`` where ``log1p`` / ``expm1``
          exist — catastrophic cancellation for small |x| (the PR 5 f32
          downlink-SNR underflow that poisoned the Fig. 5 time axis).
          Deliberately does NOT match ``log2(1 + SINR)``: that is the
          Shannon rate formula, bit-pinned across the scheduler tests.
  FLC006  a pinned error-message literal duplicated outside
          ``repro/core/errors.py`` (the FLConfig / ``ota.check_uplink``
          drift hazard) — the signatures are derived from that module's
          constants by parsing it, never importing it.
  FLC007  ``import hypothesis`` / ``import zstandard`` outside a
          ``try/except ImportError`` shim — the offline CI container does
          not ship either wheel (see requirements-dev.txt).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re

RULES = {
    "FLC001": (
        "jit/vmap/pmap of a bound method or local lambda at call time — "
        "fresh function object per call misses the jit cache; hoist to a "
        "module-level function (model/config as static args)"
    ),
    "FLC002": (
        "builtin hash()/id() is PYTHONHASHSEED-/address-salted and differs "
        "across processes; derive seeds and registry paths from "
        "zlib.crc32 of a stable encoding instead"
    ),
    "FLC003": (
        "host-sync construct on a traced value inside jit-reachable code "
        "(float()/int()/bool()/.item()/np.asarray); keep host conversions "
        "outside the traced region"
    ),
    "FLC004": (
        "Python int arithmetic crosses the jnp boundary without an "
        "explicit dtype — host ints above 2**31-1 silently overflow the "
        "default int32; pass dtype="
    ),
    "FLC005": (
        "catastrophic cancellation: log(1 + x) / 1 - exp(x) lose all "
        "precision for small |x|; use log1p(x) / expm1(x)"
    ),
    "FLC006": (
        "pinned error message duplicated as a literal; import the "
        "constant from repro.core.errors instead"
    ),
    "FLC007": (
        "hypothesis/zstandard imported outside the try/except "
        "optional-dependency shim (offline CI has neither wheel)"
    ),
}

_SUPPRESS_RE = re.compile(
    r"#\s*flcheck:\s*disable(?:=(?P<rules>[A-Z0-9,\s]+))?"
)

# `from A import B` pairs known to bind a *module* even though the checker
# cannot see A's files (external packages); first-party repro.* modules are
# resolved against the filesystem instead.
_KNOWN_MODULE_FROMS = {
    ("jax", "numpy"), ("jax", "lax"), ("jax", "random"), ("jax", "nn"),
    ("jax", "tree_util"), ("jax", "monitoring"), ("jax", "sharding"),
    ("jax", "experimental"), ("jax.experimental", "pallas"),
    ("jax", "scipy"), ("numpy", "random"), ("numpy", "linalg"),
}

# Call targets whose function-valued arguments enter traced execution.
_TRACING_TRANSFORMS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.map", "jax.lax.switch",
    "jax.experimental.shard_map.shard_map",
}

_JIT_WRAPPERS = {"jax.jit", "jax.vmap", "jax.pmap"}          # FLC001
_HOST_CASTS = {"float", "int", "bool"}                        # FLC003
_OPTIONAL_DEPS = {"hypothesis", "zstandard"}                  # FLC007
_LOG_FUNCS = {"jax.numpy.log", "numpy.log", "math.log"}       # FLC005
_EXP_FUNCS = {"jax.numpy.exp", "numpy.exp", "math.exp"}       # FLC005
_JNP_CTORS = {"jax.numpy.asarray", "jax.numpy.array"}         # FLC004


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line} {self.rule} {self.message}"


# --------------------------------------------------------------------------
# FLC006 signatures: parse repro/core/errors.py, never import it
# --------------------------------------------------------------------------

_PLACEHOLDER_RE = re.compile(r"\{[^{}]*\}")
_MIN_FRAGMENT = 24   # short literal runs ("unknown uplink ") are too generic


def pinned_fragments(errors_path: str) -> dict:
    """``{fragment: constant_name}`` from the error-constants module.

    Each UPPER_CASE string constant contributes its longest
    placeholder-free run (>= ``_MIN_FRAGMENT`` chars) as the duplication
    signature FLC006 greps literals for.
    """
    with open(errors_path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=errors_path)
    frags = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Name) and tgt.id.isupper()):
            continue
        if not (isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            continue
        runs = [r.strip() for r in _PLACEHOLDER_RE.split(node.value.value)]
        runs = [r for r in runs if len(r) >= _MIN_FRAGMENT]
        if runs:
            frags[max(runs, key=len)] = tgt.id
    return frags


def find_errors_module(search_dirs) -> str | None:
    """Locate ``repro/core/errors.py`` under the given directories."""
    for d in search_dirs:
        cand = os.path.join(d, "repro", "core", "errors.py")
        if os.path.isfile(cand):
            return cand
    return None


# --------------------------------------------------------------------------
# Per-file context: imports, module aliases, dotted-name resolution
# --------------------------------------------------------------------------

class _FileContext:
    def __init__(self, path: str, search_dirs):
        self.path = path
        self.search_dirs = list(search_dirs)
        self.alias_to_module: dict = {}   # name -> dotted module path
        self.from_imports: dict = {}      # name -> (module, original name)

    # -- import collection ---------------------------------------------------

    def collect_imports(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    self.alias_to_module[name] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.from_imports[a.asname or a.name] = (
                        node.module, a.name
                    )

    # -- module-ness ---------------------------------------------------------

    def _from_import_is_module(self, module: str, name: str) -> bool:
        if (module, name) in _KNOWN_MODULE_FROMS:
            return True
        rel = os.path.join(*module.split("."), name)
        for d in self.search_dirs:
            p = os.path.join(d, rel)
            if os.path.isdir(p) or os.path.isfile(p + ".py"):
                return True
        return False

    def is_module_name(self, name: str) -> bool:
        if name in self.alias_to_module:
            return True
        if name in self.from_imports:
            return self._from_import_is_module(*self.from_imports[name])
        return False

    # -- dotted resolution ---------------------------------------------------

    def resolve(self, node: ast.AST) -> str | None:
        """Dotted path of a Name/Attribute chain with aliases expanded.

        ``jnp.log`` -> ``jax.numpy.log``; ``jit`` (from jax import jit) ->
        ``jax.jit``; unresolvable bases return None.
        """
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = node.id
        if base in self.alias_to_module:
            head = self.alias_to_module[base]
        elif base in self.from_imports:
            mod, orig = self.from_imports[base]
            head = f"{mod}.{orig}"
        else:
            head = base
        return ".".join([head] + list(reversed(parts)))

    def module_key(self) -> str:
        """Dotted module name of this file, relative to a search dir."""
        p = os.path.normpath(self.path)
        for d in self.search_dirs:
            d = os.path.normpath(d)
            if p.startswith(d + os.sep):
                rel = p[len(d) + 1:]
                break
        else:
            rel = p
        rel = rel[:-3] if rel.endswith(".py") else rel
        parts = rel.split(os.sep)
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)


# --------------------------------------------------------------------------
# Function table for the FLC003 call graph
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _FuncInfo:
    key: tuple                 # (module_key, name)
    path: str
    is_root: bool = False
    calls: set = dataclasses.field(default_factory=set)    # callee keys
    candidates: list = dataclasses.field(default_factory=list)  # (line, desc)


def _contains_traced_call(node: ast.AST, ctx: _FileContext,
                          traced_names: set) -> bool:
    """Positive evidence the expression holds a traced value: a call into
    jax.* / jax.numpy.*, or a name previously assigned from one."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            dotted = ctx.resolve(sub.func)
            if dotted and (dotted.startswith("jax.") or dotted == "jax"):
                return True
        elif isinstance(sub, ast.Name) and sub.id in traced_names:
            return True
    return False


def _is_static_safe(node: ast.AST) -> bool:
    """Shape-/len-derived expressions are host ints even under tracing."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in (
            "shape", "ndim", "size", "dtype",
        ):
            return True
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "len"):
            return True
    return False


# --------------------------------------------------------------------------
# The per-file visitor
# --------------------------------------------------------------------------

class _Visitor(ast.NodeVisitor):
    def __init__(self, ctx: _FileContext, fragments: dict,
                 is_errors_module: bool):
        self.ctx = ctx
        self.fragments = fragments
        self.is_errors_module = is_errors_module
        self.diags: list = []        # raw (line, rule) pre-suppression
        self.funcs: dict = {}        # name -> _FuncInfo (module scope, nested flat)
        self._func_stack: list = []  # _FuncInfo currently being visited
        self._traced_stack: list = []  # per-function traced-name sets
        self._try_import_depth = 0   # inside try: ... except ImportError
        self._lambda_roots = 0

    # -- helpers -------------------------------------------------------------

    def _emit(self, node: ast.AST, rule: str) -> None:
        self.diags.append((node.lineno, rule))

    def _fn_key(self, name: str) -> tuple:
        return (self.ctx.module_key(), name)

    def _current(self) -> "_FuncInfo | None":
        return self._func_stack[-1] if self._func_stack else None

    def _resolve_callee_key(self, func: ast.AST) -> "tuple | None":
        """(module, name) of a called function, for call-graph edges."""
        if isinstance(func, ast.Name):
            name = func.id
            if name in self.ctx.from_imports:
                mod, orig = self.ctx.from_imports[name]
                return (mod, orig)
            return self._fn_key(name)
        if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
            base = func.value.id
            if base in self.ctx.alias_to_module:
                return (self.ctx.alias_to_module[base], func.attr)
            if base in self.ctx.from_imports:
                mod, orig = self.ctx.from_imports[base]
                return (f"{mod}.{orig}", func.attr)
        return None

    def _decorated_as_root(self, node) -> bool:
        for dec in node.decorator_list:
            for sub in ast.walk(dec):
                if isinstance(sub, (ast.Name, ast.Attribute)):
                    dotted = self.ctx.resolve(sub)
                    if dotted in _TRACING_TRANSFORMS:
                        return True
        return False

    # -- imports (FLC007) ----------------------------------------------------

    def _check_optional_import(self, node, modname: str) -> None:
        root = (modname or "").split(".")[0]
        if root in _OPTIONAL_DEPS and self._try_import_depth == 0:
            self._emit(node, "FLC007")

    def visit_Import(self, node):
        for a in node.names:
            self._check_optional_import(node, a.name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        self._check_optional_import(node, node.module or "")
        self.generic_visit(node)

    def visit_Try(self, node):
        catches_import = any(
            h.type is not None and any(
                isinstance(n, (ast.Name, ast.Attribute))
                and (getattr(n, "id", None) or getattr(n, "attr", None)) in (
                    "ImportError", "ModuleNotFoundError", "Exception",
                )
                for n in ast.walk(h.type)
            )
            for h in node.handlers
        )
        if catches_import:
            self._try_import_depth += 1
            for stmt in node.body:
                self.visit(stmt)
            self._try_import_depth -= 1
            for part in (node.handlers, node.orelse, node.finalbody):
                for stmt in part:
                    self.visit(stmt)
        else:
            self.generic_visit(node)

    # -- function scopes -----------------------------------------------------

    def _visit_function(self, node, name: str):
        info = self.funcs.setdefault(
            self._fn_key(name), _FuncInfo(self._fn_key(name), self.ctx.path)
        )
        if self._decorated_as_root(node):
            info.is_root = True
        # params with scalar/None defaults are config statics, not traced
        traced: set = set()
        self._func_stack.append(info)
        self._traced_stack.append(traced)
        self.generic_visit(node)
        self._traced_stack.pop()
        self._func_stack.pop()

    def visit_FunctionDef(self, node):
        self._visit_function(node, node.name)

    def visit_AsyncFunctionDef(self, node):
        self._visit_function(node, node.name)

    def visit_Lambda(self, node):
        # lambda bodies share the enclosing function's traced-name context
        self.generic_visit(node)

    # -- assignments: positive-evidence tracking for FLC003 ------------------

    def _mark_assigned(self, target, value) -> None:
        if not self._traced_stack:
            return
        if not _contains_traced_call(value, self.ctx, self._traced_stack[-1]):
            return
        names = []
        if isinstance(target, ast.Name):
            names = [target.id]
        elif isinstance(target, (ast.Tuple, ast.List)):
            names = [e.id for e in target.elts if isinstance(e, ast.Name)]
        self._traced_stack[-1].update(names)

    def visit_Assign(self, node):
        for tgt in node.targets:
            self._mark_assigned(tgt, node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._mark_assigned(node.target, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self._mark_assigned(node.target, node.value)
        self.generic_visit(node)

    # -- raise (FLC006) ------------------------------------------------------

    def visit_Raise(self, node):
        if not self.is_errors_module and self.fragments and node.exc:
            exc = node.exc
            if isinstance(exc, ast.Call) and exc.args:
                text = _literal_text(exc.args[0])
                if text and any(f in text for f in self.fragments):
                    self._emit(node, "FLC006")
        self.generic_visit(node)

    # -- binops (FLC005: 1 - exp(x)) -----------------------------------------

    def visit_BinOp(self, node):
        if isinstance(node.op, ast.Sub) and _is_const_one(node.left):
            right = node.right
            if isinstance(right, ast.Call):
                dotted = self.ctx.resolve(right.func)
                if dotted in _EXP_FUNCS:
                    self._emit(node, "FLC005")
        self.generic_visit(node)

    # -- calls: FLC001/002/003/004/005 + call graph --------------------------

    def visit_Call(self, node):
        ctx = self.ctx
        dotted = ctx.resolve(node.func)
        cur = self._current()

        # call-graph edge
        if cur is not None:
            callee = self._resolve_callee_key(node.func)
            if callee is not None:
                cur.calls.add(callee)

        # FLC001: jit/vmap/pmap of bound method / lambda at call time
        if dotted in _JIT_WRAPPERS and node.args and cur is not None:
            first = node.args[0]
            if isinstance(first, ast.Lambda):
                self._emit(node, "FLC001")
            elif isinstance(first, ast.Attribute):
                base = first.value
                while isinstance(base, ast.Attribute):
                    base = base.value
                if not (isinstance(base, ast.Name)
                        and ctx.is_module_name(base.id)):
                    self._emit(node, "FLC001")

        # FLC002: builtin hash()/id()
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("hash", "id")
                and node.func.id not in ctx.from_imports
                and node.func.id not in ctx.alias_to_module):
            self._emit(node, "FLC002")

        # FLC004: jnp.asarray/array of host int arithmetic, no dtype
        if dotted in _JNP_CTORS and node.args:
            first = node.args[0]
            has_dtype = len(node.args) >= 2 or any(
                kw.arg == "dtype" for kw in node.keywords
            )
            if (isinstance(first, ast.BinOp) and not has_dtype
                    and not _contains_traced_call(first, ctx, set())
                    and not _is_static_safe(first)):
                self._emit(node, "FLC004")

        # FLC005: log(1 + x)
        if dotted in _LOG_FUNCS and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.BinOp) and isinstance(arg.op, ast.Add):
                if _is_const_one(arg.left) or _is_const_one(arg.right):
                    self._emit(node, "FLC005")

        # FLC003 candidates (validated against jit-reachability later)
        if cur is not None:
            traced = self._traced_stack[-1] if self._traced_stack else set()
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _HOST_CASTS and node.args
                    and not _is_static_safe(node.args[0])
                    and _contains_traced_call(node.args[0], ctx, traced)):
                cur.candidates.append((node.lineno, node.func.id))
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                cur.candidates.append((node.lineno, ".item()"))
            elif (dotted in ("numpy.asarray", "numpy.array") and node.args
                    and not _is_static_safe(node.args[0])
                    and _contains_traced_call(node.args[0], ctx, traced)):
                cur.candidates.append((node.lineno, "np.asarray"))

        # transform calls: function-valued args become FLC003 roots
        if dotted in _TRACING_TRANSFORMS:
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    key = self._resolve_callee_key(arg)
                    root = self.funcs.setdefault(
                        key, _FuncInfo(key, ctx.path)
                    )
                    root.is_root = True
                elif isinstance(arg, ast.Lambda):
                    self._lambda_roots += 1
                    key = self._fn_key(f"<lambda-root:{node.lineno}:"
                                       f"{self._lambda_roots}>")
                    info = _FuncInfo(key, ctx.path, is_root=True)
                    self.funcs[key] = info
                    self._func_stack.append(info)
                    self._traced_stack.append(
                        set(self._traced_stack[-1])
                        if self._traced_stack else set()
                    )
                    self.visit(arg.body)
                    self._traced_stack.pop()
                    self._func_stack.pop()

        self.generic_visit(node)


def _is_const_one(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and node.value == 1)


def _literal_text(node: ast.AST) -> "str | None":
    """Literal text of a str Constant or the str parts of an f-string."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        return "".join(
            v.value for v in node.values
            if isinstance(v, ast.Constant) and isinstance(v.value, str)
        )
    return None


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def _suppressed_rules(lines, lineno: int, end_lineno: int) -> set:
    out: set = set()
    for ln in range(lineno, min(end_lineno, len(lines)) + 1):
        m = _SUPPRESS_RE.search(lines[ln - 1])
        if m:
            named = m.group("rules")
            if named is None:
                out.add("*")
            else:
                out.update(r.strip() for r in named.split(","))
    return out


@dataclasses.dataclass
class FileResult:
    path: str
    diags: list                 # Diagnostic (local rules, suppression applied)
    funcs: dict                 # (module, name) -> _FuncInfo
    lines: list


def check_file(path: str, *, search_dirs=("src", "."),
               fragments: "dict | None" = None) -> FileResult:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    lines = source.splitlines()
    tree = ast.parse(source, filename=path)
    ctx = _FileContext(path, search_dirs)
    ctx.collect_imports(tree)
    is_errors_module = os.path.normpath(path).endswith(
        os.path.join("repro", "core", "errors.py")
    )
    visitor = _Visitor(ctx, fragments or {}, is_errors_module)
    visitor.visit(tree)

    diags = []
    # sorted(set(...)): lambda bodies handed to transforms are walked twice
    # (as a synthetic root and via generic_visit) — never report twice
    for line, rule in sorted(set(visitor.diags)):
        sup = _suppressed_rules(lines, line, line)
        if "*" in sup or rule in sup:
            continue
        diags.append(Diagnostic(path, line, rule, RULES[rule]))
    return FileResult(path, diags, visitor.funcs, lines)


def _reachable(funcs: dict) -> set:
    roots = [k for k, f in funcs.items() if f.is_root]
    seen = set(roots)
    work = list(roots)
    while work:
        key = work.pop()
        info = funcs.get(key)
        if info is None:
            continue
        for callee in info.calls:
            if callee not in seen and callee in funcs:
                seen.add(callee)
                work.append(callee)
    return seen


def check_paths(paths, *, search_dirs=("src", "."),
                fragments: "dict | None" = None) -> list:
    """Run all rules over the given files/directories; returns Diagnostics.

    Local rules apply per file; FLC003 resolves jit-reachability over the
    union call graph of every scanned file, so cross-module reachability
    (driver in one module, traced helper in another) is honored.
    """
    files = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in ("__pycache__", ".git", "corpus")
                )
                files.extend(
                    os.path.join(dirpath, f)
                    for f in sorted(filenames) if f.endswith(".py")
                )
        elif p.endswith(".py"):
            files.append(p)

    results = [
        check_file(f, search_dirs=search_dirs, fragments=fragments)
        for f in files
    ]

    funcs: dict = {}
    for res in results:
        for key, info in res.funcs.items():
            if key in funcs:
                merged = funcs[key]
                merged.is_root = merged.is_root or info.is_root
                merged.calls |= info.calls
                merged.candidates.extend(
                    (ln, d, info.path) for ln, d in info.candidates
                )
            else:
                info.candidates = [
                    (ln, d, info.path) for ln, d in info.candidates
                ]
                funcs[key] = info

    reach = _reachable(funcs)
    lines_of = {res.path: res.lines for res in results}
    diags = [d for res in results for d in res.diags]
    for key in sorted(reach):
        info = funcs.get(key)
        if info is None:
            continue
        for ln, desc, path in info.candidates:
            sup = _suppressed_rules(lines_of.get(path, []), ln, ln)
            if "*" in sup or "FLC003" in sup:
                continue
            diags.append(Diagnostic(
                path, ln, "FLC003", f"{RULES['FLC003']} [{desc}]"
            ))
    return sorted(set(diags), key=lambda d: (d.path, d.line, d.rule))
