"""Cross-process determinism: PYTHONHASHSEED must not leak into FL state.

The PR 8 bug class: builtin ``hash()`` is salted per process, so any seed,
PRNG fold or registry ordering derived from it silently differs between
two runs of the *same* config — invalidating every cross-run scheduling /
accuracy comparison the paper makes.  flcheck's FLC002 bans the construct
statically; these tests pin the end-to-end invariant by digesting model
init and the schedule plan in subprocesses launched with *different*
``PYTHONHASHSEED`` values and requiring identical digests.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import hashlib, json
import numpy as np
import jax
from repro.config import FLConfig
from repro.core import channel, fl, scheduling
from repro.data import dirichlet_partition, make_mnist_like
from repro.models.fl_models import get_fl_model

out = {}

# per-leaf init folds (models/params.py) across registry model kinds
for name in ("lenet", "tiny-transformer"):
    params = get_fl_model(name).init(jax.random.PRNGKey(0))
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf, np.float32).tobytes())
    out[name] = h.hexdigest()

# schedule plan: lazy-gwmin (MWIS host planning) + random (own PRNG stream)
M = 8
ds = make_mnist_like(num_samples=200, seed=0)
cell = channel.CellConfig(num_devices=M)
dist = channel.sample_positions(jax.random.PRNGKey(0), cell)
gains = np.asarray(channel.sample_round_channels(
    jax.random.PRNGKey(1), dist, cell, 3))
weights = np.full(M, 1.0 / M)
for sched in ("lazy-gwmin", "random"):
    cfg = FLConfig(num_devices=M, group_size=2, num_rounds=3,
                   scheduler=sched, power_mode="max",
                   compression="adaptive", fl_engine="batched", seed=0)
    plan = fl.make_schedule(gains, weights, cell, cfg)
    h = hashlib.sha256()
    for g in plan.rounds:
        h.update(np.asarray(g, np.int64).tobytes())
    for p in plan.powers:
        h.update(np.asarray(p, np.float64).tobytes())
    out[sched] = h.hexdigest()

print("DIGESTS " + json.dumps(out))
"""


def _digests(hashseed: int) -> dict:
    env = dict(
        os.environ,
        PYTHONHASHSEED=str(hashseed),
        PYTHONPATH=os.path.join(REPO, "src"),
    )
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    for line in res.stdout.splitlines():
        if line.startswith("DIGESTS "):
            return json.loads(line[len("DIGESTS "):])
    pytest.fail(f"no digest line in subprocess output: {res.stdout[-500:]}")


def test_init_and_schedule_digests_hashseed_invariant():
    a, b = _digests(0), _digests(1)
    assert set(a) == {"lenet", "tiny-transformer", "lazy-gwmin", "random"}
    assert a == b, (
        "PYTHONHASHSEED leaked into model init or scheduling: "
        f"{[k for k in a if a[k] != b[k]]}"
    )
