"""FLC001 corpus: jit/vmap of a bound method or local lambda at call time.

The PR 7 bug: ``jax.jit(model.accuracy)`` inside the eval path built a
fresh bound-method object every call, so the jit cache missed every round
(2.2x slowdown on the legacy cell sweep).  Never executed — parsed only.
"""
import jax

from repro.models import lenet


def bad_bound_method(model, params, xb, yb):
    acc_fn = jax.jit(model.accuracy)  # expect: FLC001
    return acc_fn(params, xb, yb)


def bad_vmapped_bound_method(engine, states):
    return jax.vmap(engine.step)(states)  # expect: FLC001


def bad_local_lambda(coeff, chunks):
    f = jax.jit(lambda c: c * coeff)  # expect: FLC001
    return f(chunks)


def good_module_function(params, xb, yb):
    # module attribute (resolved against the filesystem): stable identity,
    # the jit cache hits on every call
    acc_fn = jax.jit(lenet.accuracy)
    return acc_fn(params, xb, yb)


def good_factory_call(model):
    # first argument is a call result, not a bound-method Attribute;
    # hoisting decisions are the factory's problem, not a per-call miss
    return jax.jit(make_step(model), static_argnames=("nb",))


def make_step(model):
    def step(params, batch, nb):
        return model.loss(params, batch), nb
    return step
