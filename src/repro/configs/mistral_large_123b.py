"""Mistral-Large-2407 (123B): dense GQA
[hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE = ModelConfig(
    name="mistral-large-smoke", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64,
    source="reduced mistral-large family",
)
