"""Quickstart: the paper's pipeline end-to-end in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

Builds a small NOMA cell, schedules devices with the MWIS greedy, allocates
power with MAPEL, runs a few FedAvg rounds with adaptive DoReFa compression,
and prints the accuracy trajectory.
"""
import numpy as np

from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like

M, K, T = 30, 3, 6

print("== 1. world: synthetic MNIST-like dataset, non-iid across", M, "devices")
ds = make_mnist_like(num_samples=2500, seed=0)
cell = channel.CellConfig(num_devices=M)
shards = dirichlet_partition(ds.y_train, M, seed=0)
print(f"   train={len(ds.x_train)} test={len(ds.x_test)} "
      f"device sizes: min={min(map(len, shards))} max={max(map(len, shards))}")

print(f"== 2. FL over NOMA: MWIS scheduling + MAPEL power, K={K}, T={T}")
cfg = FLConfig(num_devices=M, group_size=K, num_rounds=T,
               scheduler="lazy-gwmin", power_mode="mapel",
               compression="adaptive", seed=0)
res = fl.run_federated_learning(
    ds, shards, cell, cfg, uplink="noma",
    progress=lambda log: print(
        f"   round {log.round}: devices={log.devices} "
        f"rates={np.round(log.rates, 2)} bits={log.bits} "
        f"acc={log.test_accuracy:.3f} t={log.wall_time_s:.1f}s"))

print(f"== 3. final accuracy {res.accuracies()[-1]:.3f} "
      f"(scheme {res.scheme})")
assert res.accuracies()[-1] > res.accuracies()[0]
