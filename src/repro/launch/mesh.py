"""Production mesh construction (MULTI-POD DRY-RUN step 1).

A function, not a module constant: importing this module never touches jax
device state. Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the leading "pod" axis
carries the cross-pod data-parallel (gradient all-reduce) traffic.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices=None):
    """1-device mesh with the same axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=devices)


def cell_mesh(shards: int):
    """1-D mesh of the first ``shards`` local devices over the FL
    simulator's cell axis (``repro.sharding.rules.CELL_AXIS``).

    The multi-cell sweep shards whole independent simulations over it
    (``fl_engine.run_horizon_sharded``); like the scheduler's vertex mesh,
    callers clamp ``shards`` to the local device count rather than failing.
    """
    import numpy as np
    from jax.sharding import Mesh

    from repro.sharding.rules import CELL_AXIS

    if not 1 <= shards <= jax.local_device_count():
        raise ValueError(
            f"cell_mesh needs 1 <= shards <= {jax.local_device_count()} "
            f"local devices (got {shards})"
        )
    return Mesh(np.asarray(jax.local_devices()[:shards]), (CELL_AXIS,))
