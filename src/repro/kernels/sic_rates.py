"""Pallas kernel for batched SIC weighted-sum-rate scoring (paper §III-A).

Layout (DESIGN.md §3 conventions): the (V, K) candidate batch is transposed
to (K, V) so the huge V axis rides the 128-wide lane dimension and K (<= 8
after padding) sits on sublanes; the grid streams (K_PAD, BLOCK_V) tiles.

Inside a tile the decode order is *not* materialized with a sort: K is tiny,
so the suffix interference sum is computed with the O(K^2) comparison matrix

    tail_i = sum_j rx_j * [ rx_j < rx_i  or  (rx_j == rx_i and j > i) ]

which is exactly "sum of receive powers decoded after i" under the
descending-rx, ties-by-lower-index order that the numpy engine
(``repro.core.rates``) and its jnp mirror (``repro.core.rates_jax``, the
device-resident MWIS greedy's scorer) use via a stable argsort.  The double
loop is
unrolled at trace time (K static), so the kernel is pure VPU elementwise
work — no gather, no sort network.

Zero-padded sublane rows (rx = w = 0) are decoded last among ties by the
j > i rule and carry zero weight, so padding never perturbs real rates.

Runs under ``interpret=True`` on this CPU container; the same ``pallas_call``
lowers to Mosaic on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU lane width
BLOCK_V = 512       # candidate groups per grid step (4 lanes of 128)
K_PAD = 8           # f32 sublane tile: pad the NOMA group axis to 8


def _sic_kernel(rx_ref, w_ref, o_ref, *, k: int, noise: float):
    rx = rx_ref[...].astype(jnp.float32)        # (K_PAD, BLOCK_V)
    w = w_ref[...].astype(jnp.float32)
    acc = jnp.zeros((1, rx.shape[1]), jnp.float32)
    for i in range(k):
        rxi = rx[i : i + 1, :]
        tail = jnp.zeros_like(rxi)
        for j in range(k):
            if j == i:
                continue
            rxj = rx[j : j + 1, :]
            decoded_after = (rxj < rxi) | ((rxj == rxi) & (j > i))
            tail = tail + jnp.where(decoded_after, rxj, 0.0)
        acc = acc + w[i : i + 1, :] * jnp.log2(1.0 + rxi / (tail + noise))
    o_ref[...] = acc


def sic_weighted_rates_pallas(
    powers_vk: jax.Array,
    gains_vk: jax.Array,
    weights_vk: jax.Array,
    noise_power: float,
    *,
    interpret: bool = True,
) -> jax.Array:
    """(V, K) powers/gains/weights -> (V,) weighted SIC sum rates."""
    v, k = powers_vk.shape
    if k > K_PAD:
        raise ValueError(
            f"sic_weighted_rates_pallas supports NOMA groups of K <= {K_PAD} "
            f"(got K={k}); use the jnp reference path for larger groups"
        )
    if v == 0:
        # A grid of 0 blocks is illegal (padding can't grow an empty axis to
        # BLOCK_V); an empty candidate batch scores to an empty result.
        return jnp.zeros((0,), jnp.float32)
    rx = (powers_vk * gains_vk * gains_vk).astype(jnp.float32).T   # (K, V)
    w = weights_vk.astype(jnp.float32).T
    pad_v = (-v) % BLOCK_V
    rx = jnp.pad(rx, ((0, K_PAD - k), (0, pad_v)))
    w = jnp.pad(w, ((0, K_PAD - k), (0, pad_v)))
    vp = v + pad_v
    out = pl.pallas_call(
        functools.partial(_sic_kernel, k=k, noise=float(noise_power)),
        grid=(vp // BLOCK_V,),
        in_specs=[
            pl.BlockSpec((K_PAD, BLOCK_V), lambda i: (0, i)),
            pl.BlockSpec((K_PAD, BLOCK_V), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_V), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, vp), jnp.float32),
        interpret=interpret,
    )(rx, w)
    return out[0, :v]
