"""MAPEL power allocation quality/latency vs grid oracle and max-power
baseline (paper §III-C / ref [8])."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import power

NOISE = 1.6e-14
PMAX = 0.01


def main(fast: bool = False):
    rng = np.random.default_rng(0)
    n = 5 if fast else 10
    ratios_grid, ratios_max, times = [], [], []
    for seed in range(n):
        r = np.random.default_rng(seed)
        gains = np.abs(r.normal(1e-6, 5e-7, 3)) + 1e-8
        w = r.dirichlet(np.ones(3))
        us = timeit(lambda: power.mapel(gains, w, PMAX, NOISE), repeats=1)
        times.append(us)
        sol = power.mapel(gains, w, PMAX, NOISE)
        grid = power.grid_oracle(gains, w, PMAX, NOISE, points=15)
        maxp = power.weighted_rate(power.max_power(gains, PMAX), gains, w, NOISE)
        ratios_grid.append(sol.weighted_rate / grid.weighted_rate)
        ratios_max.append(sol.weighted_rate / max(maxp, 1e-12))
    emit("power.mapel_us", float(np.median(times)),
         f"vs_grid {np.mean(ratios_grid):.4f}")
    emit("power.mapel_vs_maxpower", float(np.median(times)),
         f"gain {np.mean(ratios_max):.4f}x")


if __name__ == "__main__":
    main()
