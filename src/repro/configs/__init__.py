"""Config registry: ``get_config(arch_id)`` -> (CONFIG, SMOKE)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "zamba2_7b",
    "qwen3_8b",
    "seamless_m4t_medium",
    "llama_3_2_vision_90b",
    "granite_34b",
    "qwen2_0_5b",
    "llama4_scout_17b_a16e",
    "mixtral_8x22b",
    "mamba2_130m",
    "mistral_large_123b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(arch: str) -> str:
    a = arch.replace(".", "_")
    return _ALIAS.get(a, a.replace("-", "_"))


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
