from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_global_norm,
    tree_zeros_like,
)
