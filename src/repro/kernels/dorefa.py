"""Pallas TPU kernels for DoReFa gradient quantization (paper §II-B).

TPU adaptation (DESIGN.md §3): the quantizer is pure VPU elementwise work.
We tile the flattened gradient as (rows, 128) — 128 matches the TPU lane
width — and stream (BLOCK_ROWS, 128) tiles HBM->VMEM per grid step. The
global max-abs scale is a cheap XLA reduction done by the ops.py wrapper
(two-pass scheme); the kernels are single-pass elementwise given the scale.

All kernels run under ``interpret=True`` on CPU for validation; on real TPU
hardware the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128          # TPU lane width: last-dim tile must be a multiple
BLOCK_ROWS = 256    # (256, 128) fp32 tile = 128 KiB VMEM per operand


def _levels(bits: int) -> float:
    return float(2 ** int(bits) - 1)


# --------------------------------------------------------------------------
# quantize -> int32 codes
# --------------------------------------------------------------------------

def _quantize_kernel(x_ref, scale_ref, o_ref, *, a: float):
    x = x_ref[...].astype(jnp.float32)
    # Divide (not multiply-by-reciprocal): the reciprocal is 1 ulp off the
    # oracle's x / scale, which flips round() at exact .5 boundaries for
    # bits >= 16 (caught by test_pack_unpack_roundtrip[16]).
    xn = jnp.clip(x / jnp.maximum(scale_ref[0], 1e-12), -1.0, 1.0)
    # round-half-away-from-zero == jnp.round (banker's) differences only at
    # exact .5 of representable values; we match jnp.round for oracle parity.
    o_ref[...] = jnp.round(a * xn).astype(jnp.int32)


def quantize_codes_pallas(
    x2d: jax.Array, scale: jax.Array, bits: int, *, interpret: bool = True
) -> jax.Array:
    """x2d: (R, 128) float -> (R, 128) int32 codes. R % BLOCK_ROWS == 0."""
    rows = x2d.shape[0]
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, a=_levels(bits)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),  # scalar scale, whole array
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        interpret=interpret,
    )(x2d, scale.reshape(1))


# --------------------------------------------------------------------------
# dequantize codes -> float32
# --------------------------------------------------------------------------

def _dequantize_kernel(c_ref, scale_ref, o_ref, *, a: float):
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = c * (scale_ref[0] / a)


def dequantize_codes_pallas(
    codes2d: jax.Array, scale: jax.Array, bits: int, *, interpret: bool = True
) -> jax.Array:
    rows = codes2d.shape[0]
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, a=_levels(bits)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(codes2d, scale.reshape(1))


# --------------------------------------------------------------------------
# fused quantize->dequantize (the in-train-step uplink simulation)
# --------------------------------------------------------------------------

def _qdq_kernel(x_ref, scale_ref, o_ref, *, a: float):
    x = x_ref[...].astype(jnp.float32)
    s = jnp.maximum(scale_ref[0], 1e-12)
    xn = jnp.clip(x / s, -1.0, 1.0)
    q = jnp.round(a * xn) / a
    o_ref[...] = (q * s).astype(o_ref.dtype)


def quantize_dequantize_pallas(
    x2d: jax.Array, scale: jax.Array, bits: int, *, interpret: bool = True
) -> jax.Array:
    rows = x2d.shape[0]
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_qdq_kernel, a=_levels(bits)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), x2d.dtype),
        interpret=interpret,
    )(x2d, scale.reshape(1))
