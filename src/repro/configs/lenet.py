"""LeNet-300-100 on (synthetic) MNIST: the paper's own experiment model."""
from repro.config import FLConfig, ModelConfig

CONFIG = ModelConfig(
    name="lenet-300-100", family="mlp",
    num_layers=2, d_model=300, num_heads=0, num_kv_heads=0,
    d_ff=100, vocab_size=10,
    source="paper §IV (LeCun & Cortes 1998 MNIST; 266,610 params)",
)

SMOKE = CONFIG

FL = FLConfig()
