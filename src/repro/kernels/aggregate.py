"""Pallas kernel for the PS-side fused dequant + weighted aggregation.

Server aggregation (paper Algorithm 1 line 10): theta update is the weighted
sum of K dequantized client payloads. Fusing dequant+scale+sum keeps each
code tile in VMEM exactly once instead of K separate dequant passes +
K-way add in HBM.

Tiling: codes are flattened to (K, R, 128) with R padded up to a multiple of
BLOCK_ROWS (the pad is sliced back off, so arbitrary payload sizes work);
each grid step loads a (K, BLOCK_ROWS, 128) brick (K <= 16 in practice, so
the brick stays well under VMEM limits) and reduces over K in registers.

Two dequant modes: a single static ``bits`` (every client quantized alike,
the historical API) or a per-client ``levels`` vector a_k = 2^{b_k} - 1 for
the batched FL engine's traced adaptive bit-widths.  Codes may be int32
(packed payloads) or float32 (traced codes where b_k can reach 32 and
2^32 - 1 no longer fits an int32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dorefa import BLOCK_ROWS, LANE


def _aggregate_kernel(c_ref, coeff_ref, o_ref, *, k: int):
    # c_ref: (K, BLOCK_ROWS, LANE) codes; coeff_ref: (K,) scale*weight/a
    acc = jnp.zeros((c_ref.shape[1], c_ref.shape[2]), jnp.float32)
    for i in range(k):  # K is small and static: unrolled VPU adds
        acc = acc + c_ref[i, :, :].astype(jnp.float32) * coeff_ref[i]
    o_ref[...] = acc


def weighted_aggregate_pallas(
    codes: jax.Array,     # (K, ...) int32 or float32 codes, any trailing shape
    scales: jax.Array,    # (K,)
    weights: jax.Array,   # (K,)
    bits: int | None = None,
    *,
    levels: jax.Array | None = None,  # (K,) per-client a = 2^b - 1 (traced ok)
    interpret: bool = True,
) -> jax.Array:
    """sum_k w_k * scale_k * codes_k / a_k, shaped like ``codes[0]``.

    Exactly one of ``bits`` (static, shared by all clients) or ``levels``
    (per-client, may be traced) selects the dequant divisor.  Payloads of
    any size are padded to the (BLOCK_ROWS, LANE) tile grid internally and
    the pad is sliced off the result; K = 1 and empty payloads are legal.
    """
    if (bits is None) == (levels is None):
        raise ValueError("pass exactly one of bits= or levels=")
    k = codes.shape[0]
    out_shape = codes.shape[1:]
    n = 1
    for d in out_shape:
        n *= int(d)
    if k == 0 or n == 0:
        return jnp.zeros(out_shape, jnp.float32)
    if levels is None:
        levels = jnp.full((k,), float(2 ** int(bits) - 1), jnp.float32)
    coeff = (
        scales.astype(jnp.float32)
        * weights.astype(jnp.float32)
        / levels.astype(jnp.float32)
    )
    flat = codes.reshape(k, n)
    pad = (-n) % (BLOCK_ROWS * LANE)
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    tiles = flat.reshape(k, -1, LANE)
    rows = tiles.shape[1]
    grid = (rows // BLOCK_ROWS,)
    out = pl.pallas_call(
        functools.partial(_aggregate_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, BLOCK_ROWS, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(tiles, coeff)
    return out.reshape(-1)[:n].reshape(out_shape)
