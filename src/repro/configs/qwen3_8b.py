"""Qwen3-8B: dense, GQA, qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=12288, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64, qk_norm=True,
    source="reduced qwen3 family",
)
