from repro.models.registry import Model, build_model
