"""SeamlessM4T-medium backbone: enc-dec transformer [arXiv:2308.11596].

Card lists the 12L multimodal backbone; we instantiate 12 encoder + 12
decoder layers. The codec/mel frontend is a stub per the assignment
carve-out: input_specs() supplies frame embeddings (B, S_enc, d_model).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    encoder_layers=12,
    source="arXiv:2308.11596",
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, head_dim=64,
    encoder_layers=2,
    source="reduced seamless family",
)
