"""Pallas TPU kernels for the paper's compute hot-spots.

 - dorefa.py    : quantize / dequantize / fused q->dq (pl.pallas_call + BlockSpec)
 - aggregate.py : fused dequant + weighted server aggregation
 - sic_rates.py : batched NOMA SIC group scoring (scheduler candidate batches)
 - ops.py       : jit'd public wrappers (padding, scale pass, jnp fallback)
 - ref.py       : pure-jnp oracles used by the allclose test sweeps
"""
