"""Train an assigned architecture (reduced config) with the paper's
gradient-compression uplink in the loop.

    PYTHONPATH=src python examples/train_llm.py [--arch mixtral-8x22b] [--steps 30]

Demonstrates the LLM-scale integration (DESIGN.md §2): each optimizer step
quantizes the gradient pytree to the bit-width the NOMA rate model allows
that round. Full-scale configs are exercised via the dry-run
(repro.launch.dryrun), not by training on CPU.
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--fl-bits", type=int, default=8)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--fl-bits", str(args.fl_bits)])
