"""Gradient pytree codec (paper Algorithm 1 uplink path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import quantization as q


def _tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (37, 11)) * 0.1,
        "b": jax.random.normal(k2, (5,)) * 0.01,
        "nested": {"w2": jax.random.normal(k3, (130,)) * 2.0},
    }


def test_payload_bits():
    tree = _tree(jax.random.PRNGKey(0))
    assert C.payload_bits(tree) == (37 * 11 + 5 + 130) * 32


def test_encode_decode_matches_fused_qdq():
    tree = _tree(jax.random.PRNGKey(1))
    enc = C.encode_tree(tree, 6)
    dec = C.decode_tree(enc)
    fused = C.encode_decode_tree(tree, 6)
    for a, b in zip(jax.tree_util.tree_leaves(dec),
                    jax.tree_util.tree_leaves(fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_encoded_size_accounting():
    tree = _tree(jax.random.PRNGKey(2))
    n = 37 * 11 + 5 + 130
    enc = C.encode_tree(tree, 6)
    assert enc.total_bits == n * 7 + 3 * 32  # (b+1) bits/elem + scale/tensor
    assert enc.total_bits < C.payload_bits(tree)


def test_adaptive_bits_for_budget():
    tree = _tree(jax.random.PRNGKey(3))
    payload = C.payload_bits(tree)
    assert int(C.adaptive_bits_for_budget(tree, payload)) == 32
    assert int(C.adaptive_bits_for_budget(tree, payload / 4)) == 8
    assert int(C.adaptive_bits_for_budget(tree, 1.0)) == 1


def test_paper_exact_range_clips():
    tree = {"w": jnp.asarray([0.5, 2.0, -3.0])}
    out = C.encode_decode_tree(tree, 8, paper_exact=True)["w"]
    # values outside [-1, 1] clip under the paper's fixed range
    assert float(out[1]) == pytest.approx(1.0, abs=1e-2)
    assert float(out[2]) == pytest.approx(-1.0, abs=1e-2)
    # per-tensor scaling (our extension) preserves them
    out2 = C.encode_decode_tree(tree, 8)["w"]
    assert float(out2[1]) == pytest.approx(2.0, abs=0.05)


def test_quantized_aggregation_error_small_at_8bit():
    """End-to-end: aggregate of quantized deltas close to exact average."""
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    w = [0.5, 0.3, 0.2]
    exact = jax.tree_util.tree_map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)
    qtrees = [C.encode_decode_tree(t, 8) for t in trees]
    approx = jax.tree_util.tree_map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *qtrees)
    for a, b in zip(jax.tree_util.tree_leaves(exact),
                    jax.tree_util.tree_leaves(approx)):
        rel = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
        assert rel < 0.02


def test_error_feedback_identity():
    """EF invariant: q_t + r_t == g_t + r_{t-1} exactly (no signal lost)."""
    from repro.core.compression import error_feedback_optimizer
    from repro.optim import sgd

    opt = error_feedback_optimizer(sgd(0.1), bits=2)
    params = {"w": jnp.zeros(64)}
    state = opt.init(params)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,)) * 0.3}
    for _ in range(3):
        prev_res = state["residual"]["w"]
        params, state = opt.update(g, state, params)
        # reconstruct q from the residual identity
        q = g["w"] + prev_res - state["residual"]["w"]
        np.testing.assert_allclose(
            np.asarray(q + state["residual"]["w"]),
            np.asarray(g["w"] + prev_res), atol=1e-6)


def test_error_feedback_tracks_signal_at_1bit():
    """Over T steps the EF-compressed cumulative update approaches the true
    cumulative gradient (plain 1-bit quantization has persistent bias)."""
    from repro.core.compression import error_feedback_optimizer
    from repro.optim import sgd

    g = {"w": jnp.asarray([0.3, -0.02, 0.11, 0.9])}  # very non-uniform
    t = 12

    def run(opt):
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        for _ in range(t):
            params, state = opt.update(g, state, params)
        return np.asarray(params["w"])

    exact = -0.1 * t * np.asarray(g["w"])
    ef = run(error_feedback_optimizer(sgd(0.1), bits=1))
    err_ef = np.abs(ef - exact).max()

    plain_q = C.encode_decode_tree(g, 1)
    plain = -0.1 * t * np.asarray(plain_q["w"])
    err_plain = np.abs(plain - exact).max()
    assert err_ef < err_plain
