"""Online-policy horizon benchmark: traced scan vs the per-round loop.

Before PR 10 an online policy (update-aware, age-fair, matching-pursuit)
forced ``horizon = "per-round"``: every round paid a host round-trip —
selection on the host, power/rate finalization, budget packing, then one
device dispatch.  The traced selection protocol folds all of it into the
scanned horizon (``fl_engine._online_horizon_core``), so the whole
horizon is ONE device program with ONE host sync.

This suite measures the end-to-end horizon wall time (warm-compiled, best
of 2 passes) of ``fl.run_federated_learning`` for the same config under
``horizon in {scan, per-round}`` with the update-aware policy — the
norm-fed policy whose FL-state feedback previously *required* the host
loop.  Like the fl_cells suite, ``speedup`` is vs the repo's default
per-round driver (legacy engine — one dispatch per device per round plus
the per-round host selection/finalization/norm syncs), and
``speedup_vs_batched`` isolates what the traced scan adds on top of the
PR 5 batched round engine.  ``benchmarks/run.py`` persists the records to
``BENCH_policy.json`` (``BENCH_policy_fast.json`` under --fast/--smoke).

Settings: max power (the traced allocator), adaptive compression, NOMA
uplink — identical physics on both paths; tests/test_policy_scan.py pins
that scan and per-round produce identical schedules/bits/rates/times.
"""
from __future__ import annotations

import dataclasses
import gc
import time

import numpy as np

from benchmarks.common import emit
from repro.config import FLConfig
from repro.core import channel, fl
from repro.data import dirichlet_partition, make_mnist_like


def _horizon_seconds(ds, shards, cell, cfg, *, passes: int = 2) -> float:
    """Whole-horizon wall time, warm-compiled, best of ``passes``."""
    fl.run_federated_learning(ds, shards, cell, cfg, eval_every=10**9)
    best = np.inf
    for _ in range(passes):
        t0 = time.perf_counter()
        fl.run_federated_learning(ds, shards, cell, cfg, eval_every=10**9)
        best = min(best, time.perf_counter() - t0)
    return best


def main(fast: bool = False) -> dict:
    if fast:
        cases = [(60, 3)]
        rounds, samples = 3, 1500
    else:
        cases = [(300, 8), (1000, 8)]
        rounds, samples = 6, 12_000
    scheduler = "update-aware"
    records = []
    for m, k in cases:
        gc.collect()
        ds = make_mnist_like(num_samples=samples, seed=0)
        cell = channel.CellConfig(num_devices=m)
        shards = dirichlet_partition(ds.y_train, m, seed=0)
        cfg = FLConfig(
            num_devices=m, group_size=k, num_rounds=rounds,
            scheduler=scheduler, power_mode="max",
            compression="adaptive", fl_engine="batched",
            horizon="scan", seed=0,
        )
        scan_s = _horizon_seconds(ds, shards, cell, cfg)
        batched_s = _horizon_seconds(
            ds, shards, cell, dataclasses.replace(cfg, horizon="per-round")
        )
        legacy_s = _horizon_seconds(
            ds, shards, cell,
            dataclasses.replace(cfg, horizon="per-round", fl_engine="legacy"),
        )
        speedup = legacy_s / scan_s
        records.append({
            "scheduler": scheduler, "m": m, "k": k, "rounds": rounds,
            "scan_horizon_s": scan_s,
            "per_round_legacy_horizon_s": legacy_s,
            "per_round_batched_horizon_s": batched_s,
            "speedup": round(speedup, 2),
            "speedup_vs_batched": round(batched_s / scan_s, 2),
        })
        emit(f"policy.scan_M{m}_K{k}", scan_s * 1e6)
        emit(f"policy.per_round_M{m}_K{k}", legacy_s * 1e6,
             f"speedup {speedup:.1f}x")
    return {
        "suite": "online_policy_horizon",
        "settings": {
            "scheduler": scheduler, "power_mode": "max",
            "compression": "adaptive", "uplink": "noma",
            "rounds": rounds, "num_samples": samples,
        },
        "records": records,
    }


if __name__ == "__main__":
    main()
