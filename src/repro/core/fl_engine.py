"""Batched FL round engine: one jitted dispatch per round (Algorithm 1).

The legacy round body in :mod:`repro.core.fl` runs one host-level
``local_update`` per scheduled device per round — K separate shard uploads,
K jitted SGD scans, K eager quantize passes, and a host ``tree_map``
aggregation — so simulation wall-clock is dominated by dispatch and scales
linearly in K.  This engine (``FLConfig.fl_engine = "batched"``) folds the
whole round body into a single jitted step over a device-resident
:class:`repro.data.client_bank.ClientBank`:

  1. **gather** — the round's K shards are a K-row gather of the bank's
     (M, n_batches, bs, D) tensors; no host round-trips.
  2. **local SGD** — ``vmap`` over the client axis of the same
     ``lax.scan`` epoch the legacy loop jits (:func:`sgd_epoch` is shared,
     so the per-client math is identical), producing all K deltas in one
     dispatch; the update-aware policies' ||delta||_2 signal becomes one
     batched reduction.
  3. **adaptive quantization** — per-client bit-widths are *traced*
     (``quantization.adaptive_bits`` on the (K,) budget vector, bit-identical
     to the legacy host ints) and the whole delta stack is DoReFa-quantized
     in the same jit via ``quantization.quantize_tree``'s (K,) bits mode.
  4. **aggregation** — the weighted FedAvg sum flows through an XLA einsum
     by default, or (``FLConfig.use_pallas``) through the fused
     dequant+aggregate Pallas kernel
     ``repro.kernels.aggregate.weighted_aggregate_pallas`` with per-client
     levels (interpret mode on CPU, Mosaic on TPU).

Scheduling, power allocation, rate/budget computation, timing, and logging
stay in the :mod:`repro.core.fl` driver and are shared with the legacy
engine, so both engines consume identical schedules, budgets and bit-widths.
(One caveat: for online ``needs_norms`` policies the selection feedback is
the update norm, whose batched reduction order differs from the legacy
per-device ``_tree_l2`` at the ulp level — a near-exact score tie between
two devices could in principle resolve differently.  Scores are continuous
functions of the channel draws, so exact ties do not occur in practice and
the equality grid pins schedule identity for ``update-aware``.  Before any
observation there is no feedback at all: every path — legacy, batched and
the traced online scan, whose carry seeds its norms with the same
constant — substitutes the policy's documented cold-start estimate
(``COLD_START_NORM``, see ``scheduling.UpdateAwarePolicy``), so round-0
selection reduces to best-channel on all of them;
tests/test_policy_scan.py pins this shared behavior.)  The legacy
loop remains the oracle the batched engine is pinned against
(``tests/test_fl_engine.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import noma
from repro.core import ota as ota_lib
from repro.core import power as power_lib
from repro.core import quantization as qlib
from repro.core import rates_jax
from repro.core import scheduling as sched_lib
from repro.data.client_bank import (
    BucketedClientBank, ClientBank, EvalBank, eval_sample_plan,
)
from repro.kernels.aggregate import weighted_aggregate_pallas

ENGINES = ("legacy", "batched")
# run_federated_learning round-body implementations; FLConfig validates
# ``fl_engine`` against this tuple.  "legacy" is the per-device host loop
# (the oracle), "batched" this module's one-dispatch-per-round engine.

HORIZON_MODES = ("per-round", "scan")
# fl.py driver modes; FLConfig validates ``horizon`` against this tuple.
# "per-round" dispatches one round at a time from the host; "scan" folds
# the whole horizon into ONE device program (a lax.scan over rounds),
# vmappable over seeds and shardable over a cell mesh.  Precomputed
# schedules run :func:`run_horizon` (the fl.py driver packs the schedule
# tensors up front); online policies with the traced protocol run
# :func:`run_horizon_online`, which folds selection, power allocation and
# the budget math into the scan body and threads the policy's
# FL-state feedback (norms/participation/ages) through the carry.
# Online policies *without* the traced protocol stay per-round only
# (errors.ERR_SCAN_ONLINE_POLICY).


# --------------------------------------------------------------------------
# Shared local-SGD epoch (the single source of the per-client math)
# --------------------------------------------------------------------------

def sgd_epoch(params, x, y, lr, *, model, unroll: int = 1):
    """One pass of minibatch SGD over a device's (padded) shard.

    x: (n_batches, bs, ...); y: (n_batches, bs, ...) with -1 marking
    padding in the label positions.  ``model`` is an
    :mod:`repro.models.fl_models` FLModel (hashable, rides as a jit
    static): its ``batch_loss(params, bx, by, valid)`` owns the per-batch
    loss, with ``valid = (by >= 0)`` as f32 precomputed here so image
    models mask exactly as the historical inlined LeNet loss did.  Both
    engines run exactly this function — the legacy loop jits it per device
    (``fl._sgd_epoch``), the batched engine vmaps it over the client axis —
    so an all-padding batch contributes an exactly-zero gradient and the
    two paths apply the same update sequence.  ``unroll`` feeds
    ``lax.scan`` (numerics-neutral); the batched engine unrolls a few steps
    to cut the per-step loop overhead its one-dispatch round pays K-fold.
    """

    def step(p, batch):
        bx, by, valid = batch

        def masked_loss(p_):
            return model.batch_loss(p_, bx, by, valid)

        g = jax.grad(masked_loss)(p)
        new = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
        return new, None

    out, _ = jax.lax.scan(
        step, params, (x, y, (y >= 0).astype(jnp.float32)), unroll=unroll
    )
    return out


# --------------------------------------------------------------------------
# The jitted round step
# --------------------------------------------------------------------------

def _pallas_aggregate_leaf(leaf, bits_k, agg_w, *, compress, paper_exact):
    """Fused dequant + weighted sum of one client-stacked leaf.

    Quantizes the raw deltas to per-client integer codes (float32-held: b
    may reach 32, whose 2^32 - 1 levels overflow int32) and lets the Pallas
    kernel apply scale_k * w_k / a_k during the reduction, so the
    dequantized per-client tensors are never materialized.  A client with
    b >= 32 gets the same full-precision passthrough as every other
    quantization path (its kernel weight is zeroed and its raw delta joins
    via a separate einsum — under the paper-exact fixed [-1, 1] range the
    codes would otherwise clip it).  With ``compress=False`` the identity
    codes (scale = a = 1) reduce to the plain weighted sum.
    """
    k = leaf.shape[0]
    flat = leaf.reshape(k, -1).astype(jnp.float32)
    if compress:
        codes, scales, a = qlib.quantize_codes_batched(
            flat, bits_k,
            scales=jnp.ones((k,), jnp.float32) if paper_exact else None,
        )
        full = (bits_k >= 32).astype(jnp.float32)
        out = weighted_aggregate_pallas(
            codes, scales, agg_w * (1.0 - full), levels=a
        )
        out = out + jnp.einsum("k,kn->n", agg_w * full, flat)
    else:
        out = weighted_aggregate_pallas(
            flat, jnp.ones((k,), jnp.float32), agg_w,
            levels=jnp.ones((k,), jnp.float32),
        )
    return out.reshape(leaf.shape[1:])


def _sparse_quantize_aggregate(
    deltas, budgets, agg_w, *, payload, topk, paper_exact, use_pallas,
):
    """Top-k sparsification ∘ DoReFa over the concatenated update vector.

    Flattens the delta tree to one (K, P) matrix (sparsification picks
    coordinates of the *whole* payload, not per leaf), derives traced
    per-client (kept, bits) from the §IV budgets
    (:func:`repro.core.compression.topk_plan`), masks everything but each
    row's top-``kept`` magnitudes, DoReFa-quantizes the survivors, and
    reduces through the einsum or the (chunked) Pallas kernel.  The row
    max-abs scale is unchanged by masking (the largest-magnitude
    coordinate is always kept), so the codes match a quantize-of-masked
    oracle exactly.  Returns ``(update_tree, kept, bits)``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    k = leaves[0].shape[0]
    sizes = [int(np.prod(leaf.shape[1:])) for leaf in leaves]
    flat = jnp.concatenate(
        [leaf.reshape(k, -1).astype(jnp.float32) for leaf in leaves], axis=1
    )                                            # (K, P)
    num_params = payload // 32
    kept, bits = comp.topk_plan(num_params, budgets, topk=topk)
    masked = flat * comp.topk_mask(flat, kept)

    scales_in = jnp.ones((k,), jnp.float32) if paper_exact else None
    codes, scales, a = qlib.quantize_codes_batched(masked, bits, scales=scales_in)
    full = (bits >= 32).astype(jnp.float32)
    if use_pallas:
        out = weighted_aggregate_pallas(
            codes, scales, agg_w * (1.0 - full), levels=a
        )
        out = out + jnp.einsum("k,kn->n", agg_w * full, masked)
    else:
        out = jnp.einsum("k,kn->n", agg_w * full, masked) + jnp.einsum(
            "k,kn->n", agg_w * (1.0 - full) / a * scales, codes
        )
    parts = jnp.split(out, np.cumsum(sizes)[:-1])
    update = jax.tree_util.tree_unflatten(
        treedef,
        [p.reshape(leaf.shape[1:]) for p, leaf in zip(parts, leaves)],
    )
    return update, kept, bits


def _train_quantize_aggregate(
    params, x, y, budgets, agg_w, gains_k, noise_key,
    *, lr, epochs, payload, compress, paper_exact, use_pallas, need_norms,
    model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """The round body on gathered client rows: vmapped local SGD -> norms ->
    traced per-client quantization -> weighted aggregation.

    x: (K, nb, BS, ...); y: (K, nb, BS, ...).  The single implementation
    behind both the per-round jit (:func:`_round_step` gathers then calls
    this) and the scanned horizon (:func:`_horizon_core` calls it inside
    the ``lax.scan`` body) — the two drivers apply the identical update
    math, which is what the scan-vs-per-round equality grid pins.
    ``model`` (static FLModel) owns the per-batch loss; ``topk`` < 1
    routes compression through the top-k ∘ DoReFa stage.  Returns
    (new_params, bits (K,) int32, kept (K,) int32 — zeros when the sparse
    stage is off — and norms (K,) f32; zeros unless ``need_norms``).
    Zero-weight rows (``agg_w[k] = 0``: schedule padding in the scan path)
    still train but contribute exactly zero to the aggregate, so padded
    tail/empty rounds leave the parameters untouched.

    ``ota`` (static) swaps the digital quantize+aggregate stages for the
    over-the-air analog superposition (:func:`repro.core.ota.superpose_tree`
    — the noisy channel sum itself is the aggregate): ``gains_k`` (K,) are
    the round's channel amplitudes, ``noise_key`` (2,) uint32 seeds the
    receiver noise, and ota_noise / ota_threshold / pmax parameterize the
    signal model.  Outside OTA the two extra operands are dummy zeros the
    compiler drops (dead inputs), so the digital paths trace the identical
    program they always did; bits are logged as 32 (analog — nothing is
    quantized on air).
    """
    k = x.shape[0]

    def local_delta(xk, yk):
        new = params
        for _ in range(epochs):
            new = sgd_epoch(new, xk, yk, lr, model=model, unroll=8)
        return jax.tree_util.tree_map(lambda a, b: a - b, new, params)

    deltas = jax.vmap(local_delta)(x, y)        # leaves (K, ...)

    if need_norms:
        # the policies' norm signal: raw pre-quantization deltas (one
        # batched reduction instead of K per-device _tree_l2 host syncs)
        sq = sum(
            jnp.sum(jnp.square(leaf.reshape(k, -1).astype(jnp.float32)), axis=1)
            for leaf in jax.tree_util.tree_leaves(deltas)
        )
        norms = jnp.sqrt(sq)
    else:
        norms = jnp.zeros((k,), jnp.float32)

    kept = jnp.zeros((k,), jnp.int32)

    if ota:
        update = ota_lib.superpose_tree(
            deltas, gains_k, agg_w, noise_key,
            pmax=pmax, noise_std=ota_noise, threshold=ota_threshold,
            use_pallas=use_pallas,
        )
        new_params = jax.tree_util.tree_map(
            lambda p, u: p + u, params, update
        )
        bits = jnp.full((k,), 32, jnp.int32)
        return new_params, bits, kept, norms

    if compress and topk < 1.0:
        update, kept, bits = _sparse_quantize_aggregate(
            deltas, budgets, agg_w, payload=payload, topk=topk,
            paper_exact=paper_exact, use_pallas=use_pallas,
        )
        new_params = jax.tree_util.tree_map(
            lambda p, u: p + u, params, update
        )
        return new_params, bits, kept, norms

    if compress:
        bits = qlib.adaptive_bits(payload, budgets)     # (K,) int32, traced
    else:
        bits = jnp.full((k,), 32, jnp.int32)

    if use_pallas:
        update = jax.tree_util.tree_map(
            lambda leaf: _pallas_aggregate_leaf(
                leaf, bits, agg_w, compress=compress, paper_exact=paper_exact
            ),
            deltas,
        )
    elif compress:
        # XLA mirror of the Pallas kernel: quantize to per-client codes and
        # fold the dequant scale s_k / a_k into the reduction coefficients,
        # so the dequantized per-client trees are never materialized.  Same
        # math as ``quantization.quantize_tree`` with (K,) bits followed by
        # the weighted einsum (modulo multiplication order), including the
        # per-client b >= 32 full-precision passthrough, which becomes a
        # second einsum over the raw deltas with complementary weights.
        a = qlib.dorefa_levels(bits)
        full = (bits >= 32).astype(jnp.float32)
        w_full = agg_w * full
        w_q = agg_w * (1.0 - full) / a

        def agg_leaf(leaf):
            flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
            codes, scales, _ = qlib.quantize_codes_batched(
                flat, bits,
                scales=(
                    jnp.ones((leaf.shape[0],), jnp.float32)
                    if paper_exact else None
                ),
            )
            out = jnp.einsum("k,kn->n", w_full, flat) + jnp.einsum(
                "k,kn->n", w_q * scales, codes
            )
            return out.reshape(leaf.shape[1:])

        update = jax.tree_util.tree_map(agg_leaf, deltas)
    else:
        update = jax.tree_util.tree_map(
            lambda leaf: jnp.einsum("k,k...->...", agg_w, leaf), deltas
        )
    new_params = jax.tree_util.tree_map(lambda p, u: p + u, params, update)
    return new_params, bits, kept, norms


_ROUND_STATICS = (
    "lr", "epochs", "payload", "compress", "paper_exact",
    "use_pallas", "need_norms", "model", "topk",
    "ota", "ota_noise", "ota_threshold", "pmax",
)


@functools.partial(jax.jit, static_argnames=("nb",) + _ROUND_STATICS)
def _round_step(
    params, xb, yb, dev_idx, budgets, agg_w, gains_k, noise_key,
    *, nb, lr, epochs, payload, compress, paper_exact, use_pallas, need_norms,
    model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """gather -> shared round body (:func:`_train_quantize_aggregate`).

    ``nb`` slices the bank's global batch grid down to the scheduled
    group's own max batch count (host-known per round), so the scan never
    pays for the cell-wide largest shard; batches past a client's own
    count are still all-padding and contribute exactly-zero gradients.
    Retraces once per distinct (group size K, nb) pair.
    """
    x = xb[dev_idx, :nb]                 # (K, nb, BS, ...)
    y = yb[dev_idx, :nb]                 # (K, nb, BS, ...)
    return _train_quantize_aggregate(
        params, x, y, budgets, agg_w, gains_k, noise_key,
        lr=lr, epochs=epochs, payload=payload,
        compress=compress, paper_exact=paper_exact, use_pallas=use_pallas,
        need_norms=need_norms, model=model, topk=topk, ota=ota,
        ota_noise=ota_noise, ota_threshold=ota_threshold, pmax=pmax,
    )


@functools.partial(jax.jit, static_argnames=_ROUND_STATICS)
def _round_step_gathered(
    params, x, y, budgets, agg_w, gains_k, noise_key,
    *, lr, epochs, payload, compress, paper_exact, use_pallas, need_norms,
    model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """Round body on pre-gathered (K, nb, ...) rows — the bucketed-bank
    path, where the K-row gather spans several per-bucket banks and runs
    outside this jit (:meth:`BucketedClientBank.gather`).  Same body, so
    bucketed rounds are bit-identical to the padded bank's."""
    return _train_quantize_aggregate(
        params, x, y, budgets, agg_w, gains_k, noise_key,
        lr=lr, epochs=epochs, payload=payload,
        compress=compress, paper_exact=paper_exact, use_pallas=use_pallas,
        need_norms=need_norms, model=model, topk=topk, ota=ota,
        ota_noise=ota_noise, ota_threshold=ota_threshold, pmax=pmax,
    )


# --------------------------------------------------------------------------
# Scanned horizon: the whole precomputed-schedule simulation as ONE program
# --------------------------------------------------------------------------

_HORIZON_STATICS = (
    "nb", "lr", "epochs", "payload", "compress", "paper_exact", "use_pallas",
    "eval_full", "model", "topk", "ota", "ota_noise", "ota_threshold", "pmax",
)


def _horizon_core(
    params, dev_tk, budgets_tk, agg_tk, gains_tk, keys_t, eval_mask_t,
    eval_idx_tn, xb, yb, xe, ye,
    *, lr, epochs, payload, compress, paper_exact, use_pallas, eval_full,
    model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """One whole horizon as a single ``lax.scan`` over rounds.

    The carry is the model parameters; per-round inputs are the
    precomputed-schedule tensors the fl.py driver packed on the host —
    dev_tk (T, K) int32 device ids (0-padded past each round's true group
    size), budgets_tk (T, K) f32 uplink bit budgets, agg_tk (T, K) f32
    FedAvg weights (zero on padding, which multiplies the padded rows out
    of the aggregate exactly), gains_tk (T, K) f32 channel amplitudes and
    keys_t (T, 2) uint32 receiver-noise keys (both consumed only under the
    OTA uplink; dummy zeros otherwise), eval_mask_t (T,) bool, and
    eval_idx_tn (T, n) eval-row gather plans (ignored when ``eval_full``).
    Emits the per-round (T, K) bit-widths, (T, K) kept-coordinate counts
    (zeros unless the top-k stage is on) and (T,) sampled test accuracies
    (NaN on rounds ``eval_mask_t`` skips — the host forward-fills,
    mirroring the per-round driver's repeated-accuracy logging under
    ``eval_every``).

    Un-jitted on purpose: :func:`run_horizon` jits it directly,
    :func:`run_horizon_vmapped` vmaps it over a seeds axis, and
    :func:`run_horizon_sharded` additionally shards a leading cell axis
    over a mesh — one implementation under all three transforms.
    """

    def body(p, inp):
        dev, bud, w, g, nk, do_eval, eidx = inp
        x = xb[dev]                     # (K, nb, BS, ...)
        y = yb[dev]                     # (K, nb, BS, ...)
        p2, bits, kept, _ = _train_quantize_aggregate(
            p, x, y, bud, w, g, nk, lr=lr, epochs=epochs, payload=payload,
            compress=compress, paper_exact=paper_exact,
            use_pallas=use_pallas, need_norms=False, model=model, topk=topk,
            ota=ota, ota_noise=ota_noise, ota_threshold=ota_threshold,
            pmax=pmax,
        )

        def ev(q):
            if eval_full:
                return model.accuracy(q, xe, ye)
            return model.accuracy(q, xe[eidx], ye[eidx])

        acc = jax.lax.cond(
            do_eval, ev, lambda q: jnp.asarray(jnp.nan, jnp.float32), p2
        )
        return p2, (bits, kept, acc)

    final, (bits_t, kept_t, acc_t) = jax.lax.scan(
        body, params,
        (dev_tk, budgets_tk, agg_tk, gains_tk, keys_t, eval_mask_t,
         eval_idx_tn),
    )
    return final, bits_t, kept_t, acc_t


@functools.partial(jax.jit, static_argnames=_HORIZON_STATICS)
def run_horizon(
    params, dev_tk, budgets_tk, agg_tk, gains_tk, keys_t, eval_mask_t,
    eval_idx_tn, xb, yb, xe, ye,
    *, nb, lr, epochs, payload, compress, paper_exact, use_pallas, eval_full,
    model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """One precomputed-schedule horizon, one dispatch (see _horizon_core).

    ``nb`` slices the bank's batch grid to the horizon-wide max scheduled
    batch count (host-known, static) — the scan's shapes are fixed across
    rounds, so the per-round driver's per-group slicing becomes a single
    horizon-level slice; the extra all-padding batches contribute
    exactly-zero gradients.
    """
    return _horizon_core(
        params, dev_tk, budgets_tk, agg_tk, gains_tk, keys_t, eval_mask_t,
        eval_idx_tn, xb[:, :nb], yb[:, :nb], xe, ye,
        lr=lr, epochs=epochs, payload=payload, compress=compress,
        paper_exact=paper_exact, use_pallas=use_pallas, eval_full=eval_full,
        model=model, topk=topk, ota=ota, ota_noise=ota_noise,
        ota_threshold=ota_threshold, pmax=pmax,
    )


@functools.partial(jax.jit, static_argnames=_HORIZON_STATICS)
def run_horizon_vmapped(
    params_s, dev_stk, budgets_stk, agg_stk, gains_stk, keys_st, eval_mask_t,
    eval_idx_stn, xb, yb, xe, ye,
    *, nb, lr, epochs, payload, compress, paper_exact, use_pallas, eval_full,
    model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """A whole seed sweep (S independent horizons), one dispatch.

    Leading axis S on params / schedule tensors / eval plans / noise keys;
    the client bank and test set are shared (the sweep varies channel
    draws, model init, schedules and receiver noise — not the data).
    ``eval_mask_t`` is shared too (eval cadence is a config, not a draw).
    Row s is the same program :func:`run_horizon` runs for that seed alone.
    """
    xbs, ybs = xb[:, :nb], yb[:, :nb]

    def one(p, d, b, a, g, nk, ei):
        return _horizon_core(
            p, d, b, a, g, nk, eval_mask_t, ei, xbs, ybs, xe, ye,
            lr=lr, epochs=epochs, payload=payload, compress=compress,
            paper_exact=paper_exact, use_pallas=use_pallas,
            eval_full=eval_full, model=model, topk=topk, ota=ota,
            ota_noise=ota_noise, ota_threshold=ota_threshold, pmax=pmax,
        )

    return jax.vmap(one)(
        params_s, dev_stk, budgets_stk, agg_stk, gains_stk, keys_st,
        eval_idx_stn,
    )


@functools.lru_cache(maxsize=None)
def _sharded_horizon_fn(
    shards, nb, lr, epochs, payload, compress, paper_exact, use_pallas,
    eval_full, model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """Build (and cache) the jitted shard_map'd cell sweep for a mesh of
    ``shards`` local devices (the scheduler's vertex-reduction pattern,
    reapplied to whole simulations).  Only the leading cell axis is
    sharded; the client bank / test set are replicated and the cells never
    communicate — each mesh device runs its own (C/shards, S) block of
    vmapped horizons."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import cell_mesh
    from repro.sharding import rules

    mesh = cell_mesh(shards)
    axis = rules.CELL_AXIS

    def fn(params_cs, dev, bud, agg, gains, keys, emask, eidx, xb, yb, xe,
           ye):
        xbs, ybs = xb[:, :nb], yb[:, :nb]

        def per_seed(p, d, b, a, g, nk, ei):
            return _horizon_core(
                p, d, b, a, g, nk, emask, ei, xbs, ybs, xe, ye,
                lr=lr, epochs=epochs, payload=payload, compress=compress,
                paper_exact=paper_exact, use_pallas=use_pallas,
                eval_full=eval_full, model=model, topk=topk, ota=ota,
                ota_noise=ota_noise, ota_threshold=ota_threshold, pmax=pmax,
            )

        def per_cell(p, d, b, a, g, nk, ei):
            return jax.vmap(per_seed)(p, d, b, a, g, nk, ei)

        return jax.vmap(per_cell)(params_cs, dev, bud, agg, gains, keys, eidx)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=rules.cell_sweep_in_specs(),
        out_specs=rules.cell_sweep_out_specs(),
        check_rep=False,
    ))


def run_horizon_sharded(
    params_cs, dev_cstk, budgets_cstk, agg_cstk, gains_cstk, keys_cst,
    eval_mask_t, eval_idx_cstn, xb, yb, xe, ye,
    *, shards, nb, lr, epochs, payload, compress, paper_exact, use_pallas,
    eval_full, model, topk, ota, ota_noise, ota_threshold, pmax,
):
    """A (C, S) cells-x-seeds sweep with the cell axis sharded over a mesh.

    C must be a multiple of ``shards`` (the fl.py driver pads and
    unpads).  With ``shards = 1`` this is exactly the double-vmapped
    single-device program, which the sharded-equality test pins the
    multi-device meshes against.
    """
    fn = _sharded_horizon_fn(
        int(shards), int(nb), float(lr), int(epochs), int(payload),
        bool(compress), bool(paper_exact), bool(use_pallas), bool(eval_full),
        model, float(topk), bool(ota), float(ota_noise), float(ota_threshold),
        float(pmax),
    )
    return fn(
        params_cs, dev_cstk, budgets_cstk, agg_cstk, gains_cstk, keys_cst,
        eval_mask_t, eval_idx_cstn, xb, yb, xe, ye,
    )


# --------------------------------------------------------------------------
# Online-policy scanned horizons: selection + power + budgets in the scan
# --------------------------------------------------------------------------

_ONLINE_STATICS = _HORIZON_STATICS + (
    "scheduler", "pcfg", "uplink", "budget_scale", "need_norms",
)
# run_horizon_online's static kwargs: the precomputed-horizon statics plus
# the policy name (resolved through the registry at trace time — the
# registry entry, not a per-call instance, keys the jit cache), the
# hashable PolicyConfig (fl.py pins its non-physics fields so program
# identity depends only on K / power mode / cell physics), the uplink
# branch, the host-folded bandwidth*slot budget factor, and whether the
# policy consumes the norm feedback.


def _online_horizon_core(
    params, solo_tm, gains_tm, weights_m, sizes_m, keys_t, eval_mask_t,
    eval_idx_tn, xb, yb, xe, ye,
    *, scheduler, pcfg, uplink, budget_scale, need_norms, lr, epochs,
    payload, compress, paper_exact, use_pallas, eval_full, model, topk, ota,
    ota_noise, ota_threshold, pmax,
):
    """One whole *online-policy* horizon as a single ``lax.scan``.

    Where :func:`_horizon_core` consumes a host-precomputed schedule, this
    scan body runs the policy itself: per round it calls the traced
    selection protocol (``select_round_traced`` — masked ``lax.top_k``
    scoring or the matching-pursuit ``lax.while_loop``), allocates powers
    in closed form (``power.traced_round_powers``), prices the §IV uplink
    (``rates_jax.sic_rates`` — the same shifted-suffix-sum SIC math the
    fused GWMIN driver ``rates_jax.greedy_rounds_fused`` scores with — or
    ``noma.tdma_rates``), converts rates to bit budgets with the
    host-folded ``bandwidth * slot`` factor, and trains/aggregates through
    the same :func:`_train_quantize_aggregate` the precomputed scan uses.

    The carry is ``(params, TracedObservation)``: the policy's FL-state
    feedback — last update norms, participation counts, last-scheduled
    rounds — never leaves the device.  Carry updates scatter through
    ``where(mask, dev, M)`` indices: padding lanes point one past the end
    and JAX's default out-of-bounds-scatter drop discards them, so a
    padded lane aliasing device 0 can never corrupt device 0's state.
    The norm carry is seeded with the policy's ``COLD_START_NORM``
    (fl.py's driver builds the initial observation), though round-0
    selection only reads the participation zeros — the estimate
    convention substitutes the same constant either way.

    Emits per-round device ids, validity masks, bit-widths, kept counts
    and accuracies; the fl.py driver's single ``device_get`` of these is
    the horizon's ONE host sync, after which it rebuilds the f64 log
    tensors (rates/budgets/times) with the exact per-round host calls.
    """
    policy = sched_lib.get_policy(scheduler)
    num_devices = int(weights_m.shape[0])
    num_rounds = int(solo_tm.shape[0])
    t_arange = jnp.arange(num_rounds, dtype=jnp.int32)

    def body(carry, inp):
        p, obs = carry
        t, solo_row, g_row, nk, do_eval, eidx = inp
        dev, mask = policy.select_round_traced(
            t, solo_row, g_row, weights_m, obs, pcfg
        )
        maskf = mask.astype(jnp.float32)
        g_k = g_row[dev] * maskf
        w_k = weights_m[dev] * maskf
        p_k = power_lib.traced_round_powers(
            pcfg.power_mode, g_k, w_k, pcfg.pmax
        )
        if uplink == "tdma":
            rates_k = noma.tdma_rates(p_k, g_k, pcfg.noise_power)
        else:
            # noma and ota both log the shared-slot SIC rates; padding
            # lanes transmit zero power, receive zero rate/budget, and
            # sort behind every live lane in the SIC order
            rates_k = rates_jax.sic_rates(p_k, g_k, pcfg.noise_power)
        bud = rates_k * jnp.float32(budget_scale)
        raw = sizes_m[dev] * maskf
        agg = raw / jnp.maximum(jnp.sum(raw), 1.0)

        p2, bits, kept, norms_k = _train_quantize_aggregate(
            p, xb[dev], yb[dev], bud, agg, g_k, nk, lr=lr, epochs=epochs,
            payload=payload, compress=compress, paper_exact=paper_exact,
            use_pallas=use_pallas, need_norms=need_norms, model=model,
            topk=topk, ota=ota, ota_noise=ota_noise,
            ota_threshold=ota_threshold, pmax=pmax,
        )

        scat = jnp.where(mask, dev, num_devices)   # padding -> OOB, dropped
        part2 = obs.participation.at[scat].add(1, mode="drop")
        last2 = obs.last_round.at[scat].set(t, mode="drop")
        if need_norms:
            norms2 = obs.update_norms.at[scat].set(norms_k, mode="drop")
        else:
            norms2 = obs.update_norms
        obs2 = sched_lib.TracedObservation(norms2, part2, last2)

        def ev(q):
            if eval_full:
                return model.accuracy(q, xe, ye)
            return model.accuracy(q, xe[eidx], ye[eidx])

        acc = jax.lax.cond(
            do_eval, ev, lambda q: jnp.asarray(jnp.nan, jnp.float32), p2
        )
        return (p2, obs2), (dev, mask, bits, kept, acc)

    obs0 = sched_lib.TracedObservation.initial(
        num_devices, getattr(policy, "COLD_START_NORM", 1.0)
    )
    (final, _), (dev_tk, mask_tk, bits_t, kept_t, acc_t) = jax.lax.scan(
        body, (params, obs0),
        (t_arange, solo_tm, gains_tm, keys_t, eval_mask_t, eval_idx_tn),
    )
    return final, dev_tk, mask_tk, bits_t, kept_t, acc_t


@functools.partial(jax.jit, static_argnames=_ONLINE_STATICS)
def run_horizon_online(
    params, solo_tm, gains_tm, weights_m, sizes_m, keys_t, eval_mask_t,
    eval_idx_tn, xb, yb, xe, ye,
    *, nb, scheduler, pcfg, uplink, budget_scale, need_norms, lr, epochs,
    payload, compress, paper_exact, use_pallas, eval_full, model, topk, ota,
    ota_noise, ota_threshold, pmax,
):
    """One online-policy horizon, one dispatch (see _online_horizon_core).

    ``nb`` slices the bank to the *bank-wide* max batch count: unlike the
    precomputed scan the schedule is unknown up front, so every device
    must fit the gathered shape.  The extra all-padding batches contribute
    exactly-zero gradients (the same invariant :func:`run_horizon`
    documents), so the deltas — and the norms fed back to the policy —
    are bit-identical to the per-round engine's group-sliced ones.
    """
    return _online_horizon_core(
        params, solo_tm, gains_tm, weights_m, sizes_m, keys_t, eval_mask_t,
        eval_idx_tn, xb[:, :nb], yb[:, :nb], xe, ye,
        scheduler=scheduler, pcfg=pcfg, uplink=uplink,
        budget_scale=budget_scale, need_norms=need_norms, lr=lr,
        epochs=epochs, payload=payload, compress=compress,
        paper_exact=paper_exact, use_pallas=use_pallas, eval_full=eval_full,
        model=model, topk=topk, ota=ota, ota_noise=ota_noise,
        ota_threshold=ota_threshold, pmax=pmax,
    )


@functools.partial(jax.jit, static_argnames=_ONLINE_STATICS)
def run_horizon_online_vmapped(
    params_s, solo_stm, gains_stm, weights_m, sizes_m, keys_st, eval_mask_t,
    eval_idx_stn, xb, yb, xe, ye,
    *, nb, scheduler, pcfg, uplink, budget_scale, need_norms, lr, epochs,
    payload, compress, paper_exact, use_pallas, eval_full, model, topk, ota,
    ota_noise, ota_threshold, pmax,
):
    """An online-policy seed sweep (S independent horizons), one dispatch.

    Mirrors :func:`run_horizon_vmapped`: the per-seed axis carries the
    model inits, channel draws (and therefore solo tables) and noise keys;
    the data weights/sizes, eval cadence, client bank and test set are
    shared.  Row s is the same program :func:`run_horizon_online` runs for
    that seed alone.
    """
    xbs, ybs = xb[:, :nb], yb[:, :nb]

    def one(p, so, g, nk, ei):
        return _online_horizon_core(
            p, so, g, weights_m, sizes_m, nk, eval_mask_t, ei, xbs, ybs,
            xe, ye,
            scheduler=scheduler, pcfg=pcfg, uplink=uplink,
            budget_scale=budget_scale, need_norms=need_norms, lr=lr,
            epochs=epochs, payload=payload, compress=compress,
            paper_exact=paper_exact, use_pallas=use_pallas,
            eval_full=eval_full, model=model, topk=topk, ota=ota,
            ota_noise=ota_noise, ota_threshold=ota_threshold, pmax=pmax,
        )

    return jax.vmap(one)(params_s, solo_stm, gains_stm, keys_st, eval_idx_stn)


@functools.lru_cache(maxsize=None)
def _sharded_online_fn(
    shards, nb, scheduler, pcfg, uplink, budget_scale, need_norms, lr,
    epochs, payload, compress, paper_exact, use_pallas, eval_full, model,
    topk, ota, ota_noise, ota_threshold, pmax,
):
    """Build (and cache) the jitted shard_map'd *online* cell sweep —
    :func:`_sharded_horizon_fn` with the online core and its operand list
    (solo tables + channel rows instead of precomputed schedule tensors;
    the shared data weights/sizes replicated like the bank)."""
    from jax.experimental.shard_map import shard_map

    from repro.launch.mesh import cell_mesh
    from repro.sharding import rules

    mesh = cell_mesh(shards)

    def fn(params_cs, solo, gains, keys, emask, eidx, weights_m, sizes_m,
           xb, yb, xe, ye):
        xbs, ybs = xb[:, :nb], yb[:, :nb]

        def per_seed(p, so, g, nk, ei):
            return _online_horizon_core(
                p, so, g, weights_m, sizes_m, nk, emask, ei, xbs, ybs,
                xe, ye,
                scheduler=scheduler, pcfg=pcfg, uplink=uplink,
                budget_scale=budget_scale, need_norms=need_norms, lr=lr,
                epochs=epochs, payload=payload, compress=compress,
                paper_exact=paper_exact, use_pallas=use_pallas,
                eval_full=eval_full, model=model, topk=topk, ota=ota,
                ota_noise=ota_noise, ota_threshold=ota_threshold, pmax=pmax,
            )

        def per_cell(p, so, g, nk, ei):
            return jax.vmap(per_seed)(p, so, g, nk, ei)

        return jax.vmap(per_cell)(params_cs, solo, gains, keys, eidx)

    return jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=rules.cell_sweep_online_in_specs(),
        out_specs=rules.cell_sweep_online_out_specs(),
        check_rep=False,
    ))


def run_horizon_online_sharded(
    params_cs, solo_cstm, gains_cstm, keys_cst, eval_mask_t, eval_idx_cstn,
    weights_m, sizes_m, xb, yb, xe, ye,
    *, shards, nb, scheduler, pcfg, uplink, budget_scale, need_norms, lr,
    epochs, payload, compress, paper_exact, use_pallas, eval_full, model,
    topk, ota, ota_noise, ota_threshold, pmax,
):
    """A (C, S) online-policy cells-x-seeds sweep, cell axis sharded.

    Same contract as :func:`run_horizon_sharded`: C must be a multiple of
    ``shards`` (the fl.py driver pads and unpads), and ``shards = 1`` is
    exactly the double-vmapped single-device program.
    """
    fn = _sharded_online_fn(
        int(shards), int(nb), scheduler, pcfg, uplink, float(budget_scale),
        bool(need_norms), float(lr), int(epochs), int(payload),
        bool(compress), bool(paper_exact), bool(use_pallas), bool(eval_full),
        model, float(topk), bool(ota), float(ota_noise), float(ota_threshold),
        float(pmax),
    )
    return fn(
        params_cs, solo_cstm, gains_cstm, keys_cst, eval_mask_t,
        eval_idx_cstn, weights_m, sizes_m, xb, yb, xe, ye,
    )


# --------------------------------------------------------------------------
# Engine front-end (what the fl driver calls)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("model",))
def _eval_full(params, xe, ye, *, model):
    return model.accuracy(params, xe, ye)


@functools.partial(jax.jit, static_argnames=("model",))
def _eval_sampled(params, xe, ye, idx, *, model):
    """Client-sampled test accuracy: gather the round's eval rows, forward
    once — the ClientBank gather idiom applied to evaluation."""
    return model.accuracy(params, xe[idx], ye[idx])


class BatchedRoundEngine:
    """Round-body engine: builds the bank once, then one dispatch per round."""

    def __init__(self, dataset, shards, cfg, payload_bits: int, model=None):
        from repro.models.fl_models import get_fl_model

        self.cfg = cfg
        self.payload = int(payload_bits)
        self.model = model if model is not None else get_fl_model(cfg.model)
        bank_cls = (
            BucketedClientBank if cfg.client_bank == "bucketed" else ClientBank
        )
        self.bank = bank_cls.build(
            dataset.x_train, dataset.y_train, shards, cfg.batch_size
        )
        # Evaluation through the same gather idiom: test set resident on
        # device, per-round sampled rows precomputed (None = full eval,
        # bit-identical to the legacy accuracy over the raw test arrays).
        self.eval_bank = EvalBank.build(dataset.x_test, dataset.y_test)
        self._eval_idx = eval_sample_plan(
            self.eval_bank.num_samples, cfg.eval_sample, cfg.num_rounds,
            cfg.seed,
        )

    def evaluate(self, params, t: int) -> float:
        """Test accuracy after round t (sampled per ``FLConfig.eval_sample``).

        At ``eval_sample = 1`` this is the full-test-set accuracy, equal
        bit for bit to the legacy driver's ``model.accuracy`` call; below 1
        it evaluates the round's precomputed sample of test rows — the same
        (T, n) plan the scanned horizon consumes, so the two drivers report
        identical sampled accuracies.
        """
        if self._eval_idx is None:
            return float(_eval_full(
                params, self.eval_bank.xe, self.eval_bank.ye,
                model=self.model,
            ))
        return float(_eval_sampled(
            params, self.eval_bank.xe, self.eval_bank.ye,
            jnp.asarray(self._eval_idx[t]), model=self.model,
        ))

    def run_round(
        self, params, devs, budgets, agg_w, *, need_norms: bool, ota=None,
    ):
        """Run one round's local training + upload + aggregation.

        devs: scheduled device ids; budgets: per-device uplink bit budgets
        (the driver computed both — identically for either engine);
        agg_w: normalized FedAvg weights |D_k| / sum |D_k|.

        ``ota`` (dict or None) switches the upload to the over-the-air
        analog superposition: the driver passes ``gains`` (K,) channel
        amplitudes, ``key`` (2,) uint32 receiver-noise key and ``pmax``
        for the round (noise std / truncation threshold come from the
        config) and the aggregate becomes the noisy channel sum
        (:func:`repro.core.ota.superpose_tree`).

        Returns ``(params, bits, ratios, norms)`` with bits/ratios as
        np arrays matching the legacy per-round log entries and norms a
        list of floats (empty unless ``need_norms``).  With the top-k
        stage on, ``bits`` are the per-client DoReFa widths of the kept
        coordinates and ``ratios`` the honest sparse on-air ratios
        I / S_k (``compression.sparse_compression_ratio``).
        """
        k = len(devs)
        if k == 0:    # empty T*K > M tail round: nothing to train or send
            return params, np.zeros(0, np.int32), np.zeros(0), []
        cfg = self.cfg
        compress = cfg.compression == "adaptive"
        # slice the scan to this group's own max batch count (see _round_step)
        nb = self.bank.n_batches_for(devs)
        statics = dict(
            lr=float(cfg.learning_rate), epochs=int(cfg.local_epochs),
            payload=self.payload, compress=compress,
            paper_exact=bool(cfg.paper_exact_range),
            use_pallas=bool(cfg.use_pallas), need_norms=bool(need_norms),
            model=self.model, topk=float(cfg.topk),
        )
        if ota is not None:
            statics.update(
                ota=True, ota_noise=float(cfg.ota_noise),
                ota_threshold=float(cfg.ota_threshold),
                pmax=float(ota["pmax"]),
            )
            gains_dev = jnp.asarray(np.asarray(ota["gains"]), jnp.float32)
            key_dev = jnp.asarray(ota["key"])
        else:
            # fixed dummies: the digital paths never read them, and pinning
            # the statics avoids a retrace per (noise, threshold) config
            statics.update(
                ota=False, ota_noise=0.0, ota_threshold=0.0, pmax=0.0,
            )
            gains_dev = jnp.zeros((k,), jnp.float32)
            key_dev = jnp.zeros((2,), jnp.uint32)
        budgets_dev = jnp.asarray(np.asarray(budgets, np.float64))
        agg_dev = jnp.asarray(np.asarray(agg_w, np.float64), jnp.float32)
        if isinstance(self.bank, BucketedClientBank):
            x, y = self.bank.gather(devs, nb)
            params, bits, kept, norms = _round_step_gathered(
                params, x, y, budgets_dev, agg_dev, gains_dev, key_dev,
                **statics
            )
        else:
            params, bits, kept, norms = _round_step(
                params, self.bank.xb, self.bank.yb,
                jnp.asarray(devs, jnp.int32), budgets_dev, agg_dev,
                gains_dev, key_dev, nb=nb, **statics,
            )
        if compress and cfg.topk < 1.0:
            # honest sparse accounting: on-air size from the realized
            # (kept, bits) pair, not the dense 32-bit payload
            ratios = comp.sparse_compression_ratio(
                self.payload, np.asarray(kept), np.asarray(bits),
                self.payload // 32,
            )
        elif compress:
            # one vectorized call to the same helper the legacy loop runs
            # per device — identical f32 IEEE ops, so the recorded ratios
            # match the oracle's bit for bit
            ratios = np.asarray(
                qlib.compression_ratio(
                    self.payload, np.asarray(budgets, np.float64)
                ),
                np.float64,
            )
        else:
            ratios = np.ones(k)
        norms_out = [float(v) for v in np.asarray(norms)] if need_norms else []
        return params, np.asarray(bits), ratios, norms_out
