"""Llama-3.2-Vision-90B backbone: cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision]. Vision tower is a stub: img_feats
arrive pre-projected (B, num_image_tokens, d_model)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    cross_attn_every=5, num_image_tokens=1600, rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64,
    cross_attn_every=2, num_image_tokens=16,
    source="reduced llama-3.2-vision family",
)
