"""Non-iid device partitioning (paper §IV: "sizes and distributions both
differ"). Standard Dirichlet(alpha) class-mixture protocol + log-normal size
jitter (DESIGN.md §6.5)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(
    labels: np.ndarray,
    num_devices: int,
    *,
    alpha: float = 0.5,
    size_sigma: float = 0.4,
    min_per_device: int = 8,
    seed: int = 0,
):
    """Return list[num_devices] of index arrays into the dataset.

    Each device's class distribution ~ Dirichlet(alpha); device sizes are
    log-normal-jittered around the uniform share. Every sample is assigned to
    exactly one device, and every *realized* shard meets ``min_per_device``
    (clamped to ``len(labels) // num_devices`` when the floor is infeasible).
    """
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for idx in by_class:
        rng.shuffle(idx)

    sizes = rng.lognormal(0.0, size_sigma, num_devices)
    sizes = np.maximum(
        (sizes / sizes.sum() * len(labels)).astype(int), min_per_device
    )
    mixes = rng.dirichlet(np.full(num_classes, alpha), num_devices)

    cursor = np.zeros(num_classes, dtype=int)
    shards = []
    for d in range(num_devices):
        want = np.round(mixes[d] * sizes[d]).astype(int)
        take = []
        for c in range(num_classes):
            avail = len(by_class[c]) - cursor[c]
            n = min(want[c], avail)
            take.append(by_class[c][cursor[c] : cursor[c] + n])
            cursor[c] += n
        shards.append(np.concatenate(take) if take else np.empty(0, int))
    # Distribute any leftovers round-robin so every sample lands somewhere.
    leftovers = np.concatenate(
        [by_class[c][cursor[c] :] for c in range(num_classes)]
    )
    for i, s in enumerate(np.array_split(leftovers, num_devices)):
        shards[i] = np.concatenate([shards[i], s])
    # Enforce the floor on *realized* shards: the size clamp above applies to
    # target sizes before class pools are exhausted, and the leftover
    # round-robin only tops up the first devices, so late devices could come
    # out below ``min_per_device``.  Rebalance from the largest shards until
    # every device meets the (realizable) floor; donors never drop below it.
    floor = min(min_per_device, len(labels) // max(num_devices, 1))
    lengths = np.array([len(s) for s in shards])
    for d in range(num_devices):
        while lengths[d] < floor:
            donor = int(np.argmax(lengths))
            take = min(floor - lengths[d], lengths[donor] - floor)
            if take <= 0:
                break  # unreachable given floor <= len(labels) // num_devices
            shards[d] = np.concatenate([shards[d], shards[donor][-take:]])
            shards[donor] = shards[donor][:-take]
            lengths[d] += take
            lengths[donor] -= take
    for d in range(num_devices):
        rng.shuffle(shards[d])
    return shards
