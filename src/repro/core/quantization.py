"""DoReFa-style adaptive gradient quantization (paper §II-B, Eq. 7).

    q(pi) = (1/a) * round(a * pi),   a = 2^b - 1

The paper assumes gradients lie in [-1, 1]. For arbitrary models we add an
optional per-tensor max-abs scale (one fp32 per tensor, counted in the bit
budget); with ``scale=1`` the codec is bit-exact to Eq. (7).

Bit-width adaptation (paper §II-B): device k scheduled with rate R_k may push
``c_k = R_k * B * t`` bits in its slot. With a full-precision payload of I
bits, the compression ratio is r_k = max(I / c_k, 1) and the quantization
bit-length b_k = floor(32 / r_k), clamped to [1, 32].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dorefa_levels(bits) -> jax.Array:
    """a = 2^b - 1 (number of quantization intervals)."""
    return jnp.asarray(2.0, jnp.float32) ** jnp.asarray(bits, jnp.float32) - 1.0


def quantize(x: jax.Array, bits, *, scale=None) -> jax.Array:
    """Quantize-dequantize x to b bits (Eq. 7). bits may be a traced scalar.

    With ``scale`` (per-tensor max-abs by default) values are normalized into
    [-1, 1] first; pass ``scale=1.0`` for the paper-exact codec.
    """
    a = dorefa_levels(bits)
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    xn = xf / scale
    q = jnp.round(a * jnp.clip(xn, -1.0, 1.0)) / a
    out = q * scale
    # b >= 32 means "no compression" — pass through exactly.
    return jnp.where(jnp.asarray(bits) >= 32, xf, out).astype(x.dtype)


def quantize_int(x: jax.Array, bits: int, *, scale=None):
    """Quantize to integer codes (for bit accounting / packing).

    Returns (codes int32 in [-a, a], scale). Static ``bits`` only.
    """
    a = float(2 ** int(bits) - 1)
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    codes = jnp.round(a * jnp.clip(xf / scale, -1.0, 1.0)).astype(jnp.int32)
    return codes, scale


def dequantize_int(codes: jax.Array, bits: int, scale) -> jax.Array:
    a = float(2 ** int(bits) - 1)
    return (codes.astype(jnp.float32) / a) * scale


def _host_scalar_to_float(x):
    """Python ints become floats before entering jnp math.

    A transformer-scale payload (10^8 params x 32 bits ~ 3.2e9) exceeds
    int32: handed to a jitted computation as a Python int it raises
    ``OverflowError`` (or, pre-trace, silently wraps the §IV airtime
    budgets).  Python floats are weak-typed, so for in-range values the
    promoted f32 result is bit-identical to the historical int path —
    LeNet's 8,531,520-bit payload is exactly f32-representable.
    Traced/array operands pass through untouched.
    """
    return float(x) if isinstance(x, (int, float)) else x


def compression_ratio(payload_bits, budget_bits) -> jax.Array:
    """r = max(I / c, 1) (paper §II-B)."""
    payload_bits = _host_scalar_to_float(payload_bits)
    budget_bits = _host_scalar_to_float(budget_bits)
    return jnp.maximum(payload_bits / jnp.maximum(budget_bits, 1e-9), 1.0)


def adaptive_bits(payload_bits, budget_bits) -> jax.Array:
    """b = floor(32 / r), clamped to [1, 32]."""
    r = compression_ratio(payload_bits, budget_bits)
    return jnp.clip(jnp.floor(32.0 / r), 1.0, 32.0).astype(jnp.int32)


def quantize_codes_batched(flat: jax.Array, bits_k, *, scales=None):
    """Per-client DoReFa codes for a client-stacked (K, N) matrix (Eq. 7).

    The single owner of the batched code-generation math: row k is
    quantized to ``bits_k[k]`` bits (traced or concrete) with its own
    max-abs scale (or a caller-supplied (K,) ``scales`` vector, e.g. ones
    for the paper-exact fixed [-1, 1] range).  Codes are float32-held:
    b = 32 means a = 2^32 - 1 levels, which overflows int32.

    Returns ``(codes, scales, levels)`` — exactly what the fused
    dequant+aggregate consumers (the batched FL engine's einsum path and
    ``kernels.aggregate.weighted_aggregate_pallas``) need.
    """
    a = dorefa_levels(bits_k)
    xf = flat.astype(jnp.float32)
    if scales is None:
        scales = jnp.maximum(jnp.max(jnp.abs(xf), axis=1), 1e-12)
    codes = jnp.round(a[:, None] * jnp.clip(xf / scales[:, None], -1.0, 1.0))
    return codes, scales, a


def quantize_batched(x: jax.Array, bits_k, *, scale=None) -> jax.Array:
    """Per-client DoReFa over a client-stacked tensor (Eq. 7, batched).

    x: (K, ...) with one client per leading row; bits_k: (K,) bit-widths,
    traced or concrete.  Row k is quantized to ``bits_k[k]`` bits with its
    own max-abs scale over the trailing axes (pass ``scale=1.0`` for the
    paper-exact fixed [-1, 1] range) — elementwise identical to calling
    :func:`quantize` on each row with that row's bits, including the
    b >= 32 full-precision passthrough, but in one traced dispatch.
    """
    k = x.shape[0]
    xf = x.astype(jnp.float32)
    flat = xf.reshape(k, -1)
    svec = (
        None if scale is None
        else jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (k,))
    )
    codes, scales, a = quantize_codes_batched(flat, bits_k, scales=svec)
    q = (codes / a[:, None]) * scales[:, None]
    bits_col = jnp.asarray(bits_k).reshape(k, 1)
    out = jnp.where(bits_col >= 32, flat, q)
    return out.reshape(x.shape).astype(x.dtype)


def quantize_tree(grads, bits, *, paper_exact: bool = False):
    """Quantize-dequantize every leaf of a gradient pytree to ``bits`` bits.

    ``bits`` is either a scalar (every leaf quantized alike — the historical
    API) or a (K,) vector, in which case every leaf must carry a leading
    client axis of length K and row k is quantized to ``bits[k]`` bits
    (:func:`quantize_batched` — the batched FL engine's traced per-client
    adaptive bit-widths).

    paper_exact=True uses the fixed [-1,1] range of Eq. (7); otherwise each
    leaf carries a per-tensor (per client-row, in batched mode) max-abs
    scale.
    """
    scale = 1.0 if paper_exact else None
    if jnp.ndim(bits) == 1:
        return jax.tree_util.tree_map(
            lambda g: quantize_batched(g, bits, scale=scale), grads
        )
    return jax.tree_util.tree_map(lambda g: quantize(g, bits, scale=scale), grads)


def quantization_error(x: jax.Array, bits) -> jax.Array:
    """RMS quantization error (used by tests / benchmarks)."""
    return jnp.sqrt(jnp.mean(jnp.square(x - quantize(x, bits))))
