"""CLI: ``python -m tools.flcheck [paths ...]``.

Exit status 0 when no (non-suppressed) diagnostic fires, 1 otherwise —
the CI ``flcheck`` job gates on it.  ``--selftest`` runs the rule corpus
(every FLC rule must fire on its positive snippets and stay silent on the
negatives) and is wired into the same CI step.
"""
from __future__ import annotations

import argparse
import sys

from tools.flcheck.checker import (
    RULES, check_paths, find_errors_module, pinned_fragments,
)

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.flcheck",
        description="trace-safety & determinism lint (stdlib ast only)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to scan (default: {DEFAULT_PATHS})")
    ap.add_argument("--errors", default=None,
                    help="path to the pinned-message constants module "
                         "(default: <search>/repro/core/errors.py)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the per-rule positive/negative corpus")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, msg in sorted(RULES.items()):
            print(f"{rule}  {msg}")
        return 0

    if args.selftest:
        from tools.flcheck.selftest import run_selftest

        failures = run_selftest()
        if failures:
            for f in failures:
                print(f, file=sys.stderr)
            print(f"flcheck selftest: {len(failures)} FAILED",
                  file=sys.stderr)
            return 1
        print("flcheck selftest: all rules PASS")
        return 0

    paths = args.paths or list(DEFAULT_PATHS)
    errors_path = args.errors or find_errors_module(["src", *paths, "."])
    fragments = pinned_fragments(errors_path) if errors_path else {}
    diags = check_paths(paths, fragments=fragments)
    for d in diags:
        print(d)
    if diags:
        print(f"flcheck: {len(diags)} diagnostic(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
