"""FL model facade + registry: the payload the NOMA uplink actually moves.

The paper's scheduling/power machinery exists to move *model updates* over a
bandwidth-limited uplink, but the FL stack historically hardcoded
LeNet-on-MNIST in the round body.  This module is the seam that removes
that: an :class:`FLModel` is the small, hashable facade the FL engine
(``repro.core.fl_engine``) and driver (``repro.core.fl``) consume —

  * ``schema()`` / ``init(key)``   — the parameter pytree (the payload)
  * ``batch_loss(params, bx, by, valid)`` — masked mean loss of ONE
    minibatch; ``by`` uses the bank's -1-is-padding convention and
    ``valid = (by >= 0)`` as float32 is precomputed by the shared SGD epoch
  * ``accuracy(params, x, y)``     — test metric for the eval banks
  * ``kind``                        — "image" (flat (N, D) float features +
    (N,) labels) or "tokens" ((N, S) int32 token rows + (N, S) next-token
    labels, see :func:`repro.data.tokens.make_token_dataset`)

``FLConfig.model`` resolves here through :func:`get_fl_model`.  The default
``"lenet"`` adapter reproduces the historical round body bit for bit (same
forward, same masked-loss ops, same ``lenet.accuracy`` eval).  Token models
wrap the :mod:`repro.models.registry` family modules (dense / moe / ssm /
hybrid) with a masked next-token cross-entropy, so any registry config —
including the full ``repro.configs`` architecture zoo — trains through the
identical batched engine / scanned horizon.  Names:

  * ``"lenet"``                — the paper's LeNet-300-100 (image kind)
  * ``"tiny-transformer"``     — 2-layer d=32 dense transformer (tests)
  * ``"tiny-transformer-1m"``  — >=10^6-param dense transformer (the
    transformer-class payload the compression stack is pinned on)
  * ``"<arch_id>"`` / ``"<arch_id>:smoke"`` — any ``repro.configs`` id
    (e.g. ``qwen2_0_5b``), resolved lazily to its CONFIG / SMOKE variant.

FLModel instances are frozen dataclasses (hashable), so they ride through
``jax.jit`` static args and the sharded-horizon ``lru_cache`` unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import lenet


@dataclasses.dataclass(frozen=True)
class LenetFLModel:
    """The paper's own model: bit-compatible adapter over repro.models.lenet.

    ``batch_loss`` is the exact op sequence the pre-registry engine inlined
    (forward -> logsumexp -> take_along_axis gold -> valid-masked mean), so
    ``FLConfig(model="lenet")`` traces the identical jaxpr and the legacy
    equality grids keep their historical values.
    """

    name: str = "lenet"
    kind: str = "image"

    def schema(self):
        return lenet.schema()

    def init(self, key: jax.Array):
        from repro.models.params import init_params

        return init_params(lenet.schema(), key)

    def batch_loss(self, params, bx, by, valid):
        logits = lenet.forward(params, bx)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, by[:, None], axis=-1)[:, 0]
        per = (logz - gold) * valid
        return jnp.sum(per) / jnp.maximum(jnp.sum(valid), 1.0)

    def accuracy(self, params, x, y):
        return lenet.accuracy(params, x, y)


# Families whose ``forward(params, tokens, cfg)`` needs no extra modality
# kwargs — the FL uplink path trains language-model-shaped payloads; vlm /
# encdec need per-batch image/encoder features the ClientBank doesn't carry.
_TOKEN_FAMILIES = ("dense", "moe", "ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class TokenFLModel:
    """Next-token-prediction adapter over a registry family module.

    Shards are (n, S) int32 token rows with (n, S) shifted labels
    (:func:`repro.data.tokens.make_token_dataset`); the bank pads with
    label -1, which :func:`repro.models.layers.cross_entropy` masks, so an
    all-padding batch contributes an exactly-zero gradient — the same
    convention the image path enforces through ``valid``.
    """

    cfg: ModelConfig
    name: str
    kind: str = "tokens"

    def __post_init__(self):
        if self.cfg.family not in _TOKEN_FAMILIES:
            raise ValueError(
                f"FL token models support families {_TOKEN_FAMILIES}, got "
                f"{self.cfg.family!r} ({self.cfg.name}): vlm/encdec forwards "
                f"need modality features the client bank does not carry"
            )

    def _module(self):
        from repro.models.registry import _FAMILIES

        return _FAMILIES[self.cfg.family]

    def schema(self):
        # shards=1: FL clients hold (and upload) the whole replica — the
        # uplink is the bottleneck being studied, not tensor parallelism.
        return self._module().schema(self.cfg, shards=1)

    def init(self, key: jax.Array):
        from repro.models.params import init_params

        return init_params(self.schema(), key)

    def batch_loss(self, params, bx, by, valid):
        from repro.models import layers as L

        del valid  # cross_entropy masks by < 0 itself (identical mask)
        logits, _ = self._module().forward(params, bx, self.cfg)
        return L.cross_entropy(logits, by, vocab_size=self.cfg.vocab_size)

    def accuracy(self, params, x, y):
        """Next-token top-1 accuracy over non-padding positions."""
        logits, _ = self._module().forward(params, x, self.cfg)
        pred = jnp.argmax(logits[..., : self.cfg.vocab_size], axis=-1)
        mask = (y >= 0).astype(jnp.float32)
        hit = (pred == jnp.maximum(y, 0)).astype(jnp.float32) * mask
        return jnp.sum(hit) / jnp.maximum(jnp.sum(mask), 1.0)


TINY_TRANSFORMER = ModelConfig(
    name="fl-tiny-transformer", family="dense",
    num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
    d_ff=64, vocab_size=64, head_dim=16, tie_embeddings=True,
    source="FL engine x model equality grid (tests)",
)

TINY_TRANSFORMER_1M = ModelConfig(
    name="fl-tiny-transformer-1m", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=16_384, head_dim=16, tie_embeddings=True,
    source="transformer-class (>=1e6 param) FL payload pin",
)


_REGISTRY: dict = {}


def register_fl_model(name: str, factory: Callable[[], object]) -> None:
    """Register a named FLModel factory (idempotent re-registration)."""
    _REGISTRY[name] = factory


register_fl_model("lenet", LenetFLModel)
register_fl_model(
    "tiny-transformer",
    lambda: TokenFLModel(cfg=TINY_TRANSFORMER, name="tiny-transformer"),
)
register_fl_model(
    "tiny-transformer-1m",
    lambda: TokenFLModel(cfg=TINY_TRANSFORMER_1M, name="tiny-transformer-1m"),
)


def available_fl_models() -> tuple:
    """Registered names (the ``repro.configs`` arch-id fallback is open)."""
    return tuple(sorted(_REGISTRY))


@functools.lru_cache(maxsize=None)
def get_fl_model(name: str):
    """Resolve ``FLConfig.model`` to an FLModel.

    Explicit registrations win; otherwise ``name`` (or ``name:smoke``) is
    resolved through the :mod:`repro.configs` architecture registry, so the
    whole config zoo is reachable without per-arch boilerplate.  Raises
    ``ValueError`` on unknown names (FLConfig validation surfaces this at
    construction time).
    """
    if name in _REGISTRY:
        return _REGISTRY[name]()
    base, _, variant = name.partition(":")
    if variant not in ("", "smoke"):
        raise ValueError(
            f"unknown FL model variant {variant!r} in {name!r}; "
            f"use '<arch_id>' or '<arch_id>:smoke'"
        )
    try:
        from repro.configs import get_config, get_smoke

        cfg = get_smoke(base) if variant == "smoke" else get_config(base)
    except ImportError:
        raise ValueError(
            f"unknown FL model {name!r}; registered: "
            f"{available_fl_models()}, plus any repro.configs arch id "
            f"('<arch_id>' or '<arch_id>:smoke')"
        ) from None
    return TokenFLModel(cfg=cfg, name=name)
