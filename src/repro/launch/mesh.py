"""Production mesh construction (MULTI-POD DRY-RUN step 1).

A function, not a module constant: importing this module never touches jax
device state. Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the leading "pod" axis
carries the cross-pod data-parallel (gradient all-reduce) traffic.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices=None):
    """1-device mesh with the same axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=devices)
