"""Configuration system: model configs, input shapes, FL/cell settings.

Every assigned architecture provides a module in ``repro.configs`` exposing
``CONFIG`` (the exact published config, used only via the dry-run) and
``SMOKE`` (a reduced same-family variant for CPU tests). ``--arch <id>``
resolves through :func:`repro.configs.get_config`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | mlp
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention variants
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2
    sliding_window: Optional[int] = None    # mixtral SWA
    attention_chunk: Optional[int] = None   # llama4 block-local (iRoPE-style)
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False  # llama4 shared expert
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 128     # SSD chunk length Q (memory-term lever: the
                             # within-chunk decay matrix is O(S*Q) per head)
    ssm_bf16: bool = False   # keep the SSD einsum chain in bf16 (decay/
                             # cumsum math stays fp32) — §Perf pair A lever
    # hybrid (zamba2): one shared attention block every N mamba blocks
    hybrid_attn_every: int = 6
    # enc-dec (seamless)
    encoder_layers: int = 0
    encoder_seq: int = 0             # frame-embedding length from the stub frontend
    # vlm: one cross-attention layer every N self-attention layers
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    # numerics
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                 # citation for the config

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def padded_heads(self, shards: int = 16) -> int:
        """Q heads padded up so the head axis shards (qwen2: 14 -> 16)."""
        return _round_up(self.num_heads, shards) if self.num_heads else 0

    def padded_kv_heads(self, shards: int = 16) -> int:
        """KV heads replicated up to the shard count when kv < shards
        (MaxText-style GQA replication; DESIGN.md §4)."""
        if not self.num_kv_heads:
            return 0
        if self.num_kv_heads >= shards:
            return self.num_kv_heads
        assert shards % self.num_kv_heads == 0 or self.num_kv_heads % shards == 0
        return shards

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    def param_count(self) -> int:
        """Analytic parameter count (approximate for exotic families)."""
        d, v = self.d_model, self.padded_vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        if self.family in ("dense", "moe", "vlm", "encdec"):
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            if self.num_experts:
                ff = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
                if self.moe_shared_expert:
                    ff += 3 * d * self.d_ff
            else:
                ff = 3 * d * self.d_ff
            per_layer = attn + ff + 2 * d
        elif self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            per_layer = (
                d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nheads)
                + d_in * d
                + self.ssm_conv_width * (d_in + 2 * self.ssm_groups * self.ssm_state)
                + 2 * nheads + d_in + 2 * d
            )
        total = emb + self.num_layers * per_layer
        if self.family == "hybrid":
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
            total += attn + 3 * d * self.d_ff + 2 * d  # one shared block
        if self.family == "encdec":
            total += self.encoder_layers * (per_layer)
            total += self.num_layers * (d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d + d)
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            total += n_cross * (d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d + d)
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) params — differs from total only for MoE."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        dense_like = dataclasses.replace(self, num_experts=0, experts_per_token=0)
        base = dense_like.param_count()
        active_ff = self.experts_per_token * 3 * d * self.d_ff
        shared = 3 * d * self.d_ff if self.moe_shared_expert else 0
        # base already counts one dense FFN; replace it with active experts.
        return int(base + self.num_layers * (active_ff + shared - 3 * d * self.d_ff))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Paper §IV system settings (Table I + text)."""

    num_devices: int = 300           # M
    group_size: int = 3              # K
    num_rounds: int = 35             # T
    learning_rate: float = 0.01     # eta
    batch_size: int = 10             # B
    local_epochs: int = 1
    scheduler: str = "lazy-gwmin"    # any registered policy name: lazy-gwmin |
                                     # literal-gwmin | random | round-robin |
                                     # proportional-fair | update-aware |
                                     # age-fair | matching-pursuit
    scheduler_backend: str = "numpy"  # numpy | jax (fused while_loop, M >> 300)
                                      # | jax-stepwise (per-step device argmax)
    power_mode: str = "mapel"        # mapel | max | ota-align (uplink="ota")
    compression: str = "adaptive"    # adaptive | none
    paper_exact_range: bool = False  # DoReFa fixed [-1,1] range (Eq. 7)
    fl_engine: str = "legacy"        # legacy (per-device host loop, the
                                     # oracle) | batched (one jitted dispatch
                                     # per round over a device-resident
                                     # ClientBank; use for large M/K sweeps)
    use_pallas: bool = False         # batched engine only: aggregate through
                                     # the fused dequant+aggregate Pallas
                                     # kernel instead of the XLA einsum
    horizon: str = "per-round"       # per-round (host round loop) | scan
                                     # (the whole horizon as ONE lax.scan
                                     # device program; vmappable over seeds,
                                     # shardable over a cell mesh. Accepts
                                     # precomputed schedules and online
                                     # policies with the traced protocol —
                                     # selection/power/budgets then run
                                     # inside the scan body)
    eval_sample: float = 1.0         # fraction of the test set evaluated per
                                     # round via the EvalBank gather (batched
                                     # engine + scan horizon); 1.0 = full
                                     # test set, bit-identical to the legacy
                                     # lenet.accuracy eval
    model: str = "lenet"             # FL payload: any repro.models.fl_models
                                     # name ("lenet", "tiny-transformer",
                                     # "tiny-transformer-1m", or any
                                     # repro.configs arch id / "<id>:smoke").
                                     # "lenet" is bit-identical to the
                                     # historical hardcoded path.
    topk: float = 1.0                # sparsification stage before DoReFa:
                                     # cap on the kept-coordinate fraction
                                     # per client (traced k from the §IV bit
                                     # budgets, see compression.topk_plan);
                                     # 1.0 = dense (off). Batched engine /
                                     # scan horizon only.
    client_bank: str = "padded"      # padded (one dense (M, NB, ...) bank,
                                     # NB = global max batches) | bucketed
                                     # (size-bucketed banks, pow-2 batch
                                     # counts — skewed Dirichlet shards stop
                                     # padding to the global max; batched
                                     # per-round engine only)
    uplink: str = "noma"             # noma | tdma (digital §IV uplinks) |
                                     # ota (analog over-the-air superposition,
                                     # core/ota.py: the PS receives the noisy
                                     # sum and never decodes per-device
                                     # payloads). Drivers take this as their
                                     # default; an explicit uplink= call
                                     # argument still overrides it.
    ota_noise: float = 0.0           # OTA receiver noise std sigma_ota (same
                                     # units as the update entries after the
                                     # channel inversion referral); 0 = the
                                     # exact weighted aggregate
    ota_threshold: float = 0.0       # truncated channel inversion: device k
                                     # participates iff h_k >= threshold *
                                     # max_j h_j; 0 = everyone scheduled
                                     # transmits, 1-eps = only the best
    seed: int = 0

    def __post_init__(self):
        """Fail at construction, not deep inside fl.py mid-simulation.

        Scheduler and power-mode names are checked against the live
        registries (``scheduling.available_policies`` /
        ``power.POWER_MODES``), so a freshly registered policy is valid
        here with no config change; the imports are deferred to keep
        ``repro.config`` import-light.
        """
        if self.num_rounds < 1:
            raise ValueError(f"num_rounds must be >= 1, got {self.num_rounds}")
        if not 1 <= self.group_size <= self.num_devices:
            raise ValueError(
                f"group_size must be in [1, num_devices={self.num_devices}], "
                f"got {self.group_size}"
            )
        from repro.core import errors
        from repro.core import power as power_lib
        from repro.core import scheduling

        if self.scheduler not in scheduling.available_policies():
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; registered: "
                f"{scheduling.available_policies()}"
            )
        if self.power_mode not in power_lib.POWER_MODES:
            raise ValueError(
                f"unknown power_mode {self.power_mode!r}; known: "
                f"{power_lib.POWER_MODES}"
            )
        if self.scheduler_backend not in scheduling.SCHEDULER_BACKENDS:
            raise ValueError(
                f"unknown scheduler_backend {self.scheduler_backend!r}; "
                f"known: {scheduling.SCHEDULER_BACKENDS}"
            )
        from repro.core import fl_engine

        if self.fl_engine not in fl_engine.ENGINES:
            raise ValueError(
                f"unknown fl_engine {self.fl_engine!r}; "
                f"known: {fl_engine.ENGINES}"
            )
        if self.horizon not in fl_engine.HORIZON_MODES:
            raise ValueError(
                f"unknown horizon {self.horizon!r}; "
                f"known: {fl_engine.HORIZON_MODES}"
            )
        if self.horizon == "scan" and scheduling.policy_is_online(self.scheduler):
            # Online policies run device-resident under the scan iff they
            # implement the traced selection protocol (the feedback loop
            # then lives inside the scan carry).  No silent fallback to
            # the per-round driver for the rest: the run would silently
            # be a different policy.
            if not scheduling.policy_is_traced(self.scheduler):
                raise ValueError(
                    errors.ERR_SCAN_ONLINE_POLICY.format(
                        scheduler=self.scheduler
                    )
                )
            if self.power_mode == "mapel":
                # the polyblock power search is host-iterative: it cannot
                # run inside the traced round body
                raise ValueError(
                    errors.ERR_SCAN_ONLINE_MAPEL.format(
                        scheduler=self.scheduler
                    )
                )
        if not 0.0 < self.eval_sample <= 1.0:
            raise ValueError(
                f"eval_sample must be in (0, 1], got {self.eval_sample}"
            )
        if (
            self.eval_sample < 1.0
            and self.fl_engine == "legacy"
            and self.horizon == "per-round"
        ):
            raise ValueError(
                "eval_sample < 1 requires fl_engine='batched' or "
                "horizon='scan' (the legacy loop always evaluates the full "
                "test set)"
            )
        from repro.models import fl_models

        fl_models.get_fl_model(self.model)  # raises ValueError on unknown
        if not 0.0 < self.topk <= 1.0:
            raise ValueError(f"topk must be in (0, 1], got {self.topk}")
        if (
            self.topk < 1.0
            and self.fl_engine == "legacy"
            and self.horizon == "per-round"
        ):
            raise ValueError(
                "topk < 1 requires fl_engine='batched' or horizon='scan' "
                "(the legacy oracle loop is dense DoReFa only)"
            )
        if self.topk < 1.0 and self.compression != "adaptive":
            raise ValueError(
                "topk < 1 requires compression='adaptive': the sparse "
                "(kept, bits) split is derived from the same per-client "
                "bit budgets that drive the adaptive DoReFa widths"
            )
        if self.client_bank not in ("padded", "bucketed"):
            raise ValueError(
                f"unknown client_bank {self.client_bank!r}; "
                f"known: ('padded', 'bucketed')"
            )
        if self.client_bank == "bucketed" and not (
            self.fl_engine == "batched" and self.horizon == "per-round"
        ):
            raise ValueError(
                "client_bank='bucketed' requires fl_engine='batched' with "
                "horizon='per-round': the scan horizon indexes one dense "
                "(M, NB, ...) bank inside the traced program"
            )
        from repro.core import ota as ota_lib

        # Incoherent-uplink combos fail here, mirroring the scan+online
        # guard above; the same check reruns in the fl.py drivers because
        # uplink can also arrive as a call-site override.
        ota_lib.check_uplink(
            self.uplink, compression=self.compression, topk=self.topk,
            power_mode=self.power_mode,
        )
        if self.ota_noise < 0.0:
            raise ValueError(
                f"ota_noise must be >= 0, got {self.ota_noise}"
            )
        if not 0.0 <= self.ota_threshold < 1.0:
            raise ValueError(
                f"ota_threshold must be in [0, 1), got {self.ota_threshold}"
            )
