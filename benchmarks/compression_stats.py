"""Paper §II-B: adaptive compression statistics over a simulated horizon —
average compression ratio, bit-width distribution, and quantization error
vs rate."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import build_world, emit
from repro.config import FLConfig
from repro.core import channel, fl, noma
from repro.core import quantization as q


def main(fast: bool = False):
    world = build_world(num_devices=60, num_samples=2000)
    rounds = 6 if fast else 12
    cfg = FLConfig(num_devices=60, group_size=3, num_rounds=rounds,
                   scheduler="lazy-gwmin", power_mode="max")
    t0 = time.perf_counter()
    res = fl.run_federated_learning(world.dataset, world.shards, world.cell,
                                    cfg, uplink="noma")
    us = (time.perf_counter() - t0) * 1e6
    bits = np.concatenate([l.bits for l in res.logs])
    ratios = np.concatenate([l.compression_ratios for l in res.logs])
    emit("compress.mean_bits", us, f"{bits.mean():.2f}")
    emit("compress.mean_ratio", us, f"{ratios.mean():.1f}x")
    emit("compress.min_bits", us, str(int(bits.min())))

    # error vs bits curve (static)
    x = jax.random.normal(jax.random.PRNGKey(0), (100_000,)) * 0.1
    errs = {b: float(q.quantization_error(x, b)) for b in (1, 2, 4, 8, 16)}
    emit("compress.rmse_curve", 0.0,
         " ".join(f"b{b}={e:.2e}" for b, e in errs.items()))


if __name__ == "__main__":
    main()
