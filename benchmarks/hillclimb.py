"""Perf hillclimb harness (EXPERIMENTS.md §Perf).

For a chosen (arch x shape) pair, compile a set of lever variants and report
the roofline-term deltas. Two measurements per variant:

  * component-extrapolated roofline (reduced-depth UNROLLED compiles) — the
    compute/memory/collective terms; levers act per-layer so reduced-depth
    deltas transfer to full depth;
  * full-depth SCANNED compile — per-device memory_analysis (the "fits"
    check).

    PYTHONPATH=src python -m benchmarks.hillclimb --arch zamba2-7b \
        --shape train_4k --variants baseline,accum8,accum8_noremat

NOTE: must run in a fresh process (sets the 512-device XLA flag).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json

VARIANTS = {
    # name -> kwargs for run_one / roofline_extrapolated
    "baseline": {},
    "accum4": {"grad_accum": 4},
    "accum8": {"grad_accum": 8},
    "accum16": {"grad_accum": 16},
    "accum8_noremat": {"grad_accum": 8, "remat": False},
    "noremat": {"remat": False},
    "nofl": {"fl_bits": 32},
    "kv512": {"kv_chunk_train": 512},
    "kv2048": {"kv_chunk_train": 2048},
    "kv4096": {"kv_chunk_train": 4096},
    "kvdec1024": {"kv_chunk_decode": 1024},
    "kvdec16384": {"kv_chunk_decode": 16384},
    "ssd64": {"cfg_override": {"ssm_chunk": 64}},
    "ssd256": {"cfg_override": {"ssm_chunk": 256}},
    "ssd64_accum8": {"cfg_override": {"ssm_chunk": 64}, "grad_accum": 8},
    "accum16_v": {"grad_accum": 16},
    "ssmbf16": {"cfg_override": {"ssm_bf16": True}},
    "ssmbf16_accum8": {"cfg_override": {"ssm_bf16": True}, "grad_accum": 8},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline,accum8")
    ap.add_argument("--skip-mem", action="store_true",
                    help="skip the full-depth scanned memory compile")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import roofline_extrapolated, run_one

    rows = []
    for name in args.variants.split(","):
        kw = dict(VARIANTS[name])
        fl_bits = kw.pop("fl_bits", 8)
        roof = roofline_extrapolated(args.arch, args.shape, fl_bits=fl_bits,
                                     verbose=False, **kw)
        mem = None
        if not args.skip_mem:
            mem = run_one(args.arch, args.shape, unroll=False, verbose=False,
                          fl_bits=fl_bits, **kw)
        row = {"variant": name, "kw": {**kw, "fl_bits": fl_bits}}
        if roof is not None and roof.status == "OK":
            s = roof.roofline
            row.update(
                t_compute_ms=s["t_compute_s"] * 1e3,
                t_memory_ms=s["t_memory_s"] * 1e3,
                t_collective_ms=s["t_collective_s"] * 1e3,
                bottleneck=s["bottleneck"],
                useful=s["useful_flops_ratio"],
            )
        if mem is not None and mem.status == "OK":
            row["mem_per_dev_gib"] = mem.bytes_per_device / 2**30
            row["compile_s"] = mem.compile_s
        if mem is not None and mem.status == "FAIL":
            row["mem_error"] = mem.error[:120]
        rows.append(row)
        print(json.dumps(row), flush=True)

    if args.out:
        with open(args.out, "a") as f:
            for r in rows:
                f.write(json.dumps({"arch": args.arch, "shape": args.shape,
                                    **r}) + "\n")


if __name__ == "__main__":
    main()
