"""Client banks: padded vs bucketed layouts, memory accounting, token shards.

The padded ClientBank bills every client for the single largest shard's
batch grid; BucketedClientBank groups clients into power-of-two batch-count
buckets so within-bucket padding stays below 2x.  The contract pinned here:
a round's gathered (K, nb, BS, ...) rows are element-equal between the two
layouts (so training through either is bit-identical — the engine-level
equality lives in test_fl_engine.py), ``nbytes`` reports the real device
footprint, and ``build`` warns when the padded bank would claim too much
of the device's memory.  Both layouts must accept token-shaped shards
((S,) rows with (S,) labels) unchanged.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import client_bank as cb
from repro.data.client_bank import BucketedClientBank, ClientBank
from repro.data.tokens import make_token_dataset


def _skewed_world(rng, *, m=9, d=7):
    """Shard sizes spanning several pow-2 batch buckets (bs=4):
    1..3 batches needed for most, 17 batches for the one huge shard."""
    sizes = [3, 4, 5, 8, 9, 12, 12, 20, 65]
    n = sum(sizes)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    idx = np.arange(n)
    shards, at = [], 0
    for s in sizes:
        shards.append(idx[at:at + s])
        at += s
    return x, y, shards


def test_bucketed_gather_matches_padded_rows(rng):
    x, y, shards = _skewed_world(rng)
    padded = ClientBank.build(x, y, shards, 4)
    bucketed = BucketedClientBank.build(x, y, shards, 4)
    assert bucketed.num_devices == padded.num_devices
    np.testing.assert_array_equal(bucketed.sizes, padded.sizes)
    for devs in ([0], [8, 0], [3, 7, 1], [2, 4, 6, 8], list(range(9))):
        nb = bucketed.n_batches_for(devs)
        assert nb == padded.n_batches_for(devs)
        gx, gy = bucketed.gather(devs, nb)
        np.testing.assert_array_equal(
            np.asarray(gx), np.asarray(padded.xb[jnp.asarray(devs), :nb]))
        np.testing.assert_array_equal(
            np.asarray(gy), np.asarray(padded.yb[jnp.asarray(devs), :nb]))


def test_bucketed_buckets_are_pow2_and_smaller(rng):
    x, y, shards = _skewed_world(rng)
    padded = ClientBank.build(x, y, shards, 4)
    bucketed = BucketedClientBank.build(x, y, shards, 4)
    for xb, _ in bucketed.buckets:
        nb = xb.shape[1]
        assert nb & (nb - 1) == 0, f"bucket grid {nb} not a power of two"
    # every client's bucket grid is below 2x its own need...
    for k in range(len(shards)):
        xb, _ = bucketed.buckets[bucketed.bucket_of[k]]
        need = ClientBank._ceil_batches(len(shards[k]), 4)
        assert need <= xb.shape[1] < 2 * need
    # ...so the skewed partition stops paying for the global max grid
    assert bucketed.nbytes < padded.nbytes


def test_padded_nbytes_exact(rng):
    x, y, shards = _skewed_world(rng, d=7)
    bank = ClientBank.build(x, y, shards, 4)
    m, nb, bs = 9, 17, 4       # max shard 65 -> ceil(65/4) = 17 batches
    assert bank.xb.shape == (m, nb, bs, 7)
    assert bank.nbytes == m * nb * bs * 7 * 4 + m * nb * bs * 4


def test_padded_build_warns_over_memory_fraction(rng, monkeypatch):
    x, y, shards = _skewed_world(rng)
    bank_bytes = ClientBank.build(x, y, shards, 4).nbytes
    # pretend the device only has 1.5x the bank: 50% fraction must trip
    monkeypatch.setattr(cb, "_device_memory_limit",
                        lambda: int(1.5 * bank_bytes))
    with pytest.warns(ResourceWarning, match="bucketed"):
        ClientBank.build(x, y, shards, 4)
    # a roomy device stays silent
    monkeypatch.setattr(cb, "_device_memory_limit",
                        lambda: int(100 * bank_bytes))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ClientBank.build(x, y, shards, 4)


def test_bank_memory_warning_edges(rng, monkeypatch):
    """The accounting's edge contract: an unreported or nonsensical device
    limit never warns (CPU backends return None), ``mem_fraction`` is a
    real knob (a tight fraction trips even a roomy device), and the
    bucketed layout — the remedy the warning recommends — builds silently
    on any device."""
    x, y, shards = _skewed_world(rng)
    bank_bytes = ClientBank.build(x, y, shards, 4).nbytes
    for no_limit in (None, 0, -1):
        monkeypatch.setattr(cb, "_device_memory_limit", lambda v=no_limit: v)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ClientBank.build(x, y, shards, 4)
    monkeypatch.setattr(cb, "_device_memory_limit",
                        lambda: int(100 * bank_bytes))
    with pytest.warns(ResourceWarning, match="bucketed"):
        ClientBank.build(x, y, shards, 4, mem_fraction=0.001)
    # one-byte device: the padded bank would warn, the remedy must not
    monkeypatch.setattr(cb, "_device_memory_limit", lambda: 1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        BucketedClientBank.build(x, y, shards, 4)


def test_token_shards_bank_shapes():
    ds = make_token_dataset(vocab_size=32, num_samples=64, seq_len=6, seed=0)
    shards = [np.arange(0, 20), np.arange(20, 33), np.arange(33, 57)]
    bank = ClientBank.build(ds.x_train, ds.y_train, shards, 8)
    assert bank.xb.shape == (3, 3, 8, 6)       # (M, NB, BS, S)
    assert bank.yb.shape == (3, 3, 8, 6)       # (S,) labels, not scalar
    # padding positions carry label -1 across the whole trailing shape
    assert np.all(np.asarray(bank.yb)[1, 2, 5:] == -1)
    bucketed = BucketedClientBank.build(ds.x_train, ds.y_train, shards, 8)
    gx, gy = bucketed.gather([1, 2], bucketed.n_batches_for([1, 2]))
    np.testing.assert_array_equal(
        np.asarray(gx), np.asarray(bank.xb[jnp.asarray([1, 2]), :3]))
    np.testing.assert_array_equal(
        np.asarray(gy), np.asarray(bank.yb[jnp.asarray([1, 2]), :3]))
