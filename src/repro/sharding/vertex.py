"""1-D device mesh over the scheduler's vertex axis.

The device-resident MWIS greedy (``repro.core.rates_jax``) scores a
(T, V, K) tensor of (round, candidate-subset) vertices per step.  For
multi-device cells the V axis is embarrassingly parallel: each device
scores its own slice of the subset enumeration and the per-shard argmaxes
are combined with in-mesh collectives (``lax.pmax`` on the score,
``lax.pmin`` on the t-major global flat index, so the numpy path's
earliest-round / lexicographically-first tie-break survives sharding).

This module owns the mesh plumbing so ``rates_jax`` stays mesh-agnostic;
it is the scheduler-side sibling of ``repro.sharding.rules`` (which maps
model parameter axes, not scheduler work).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

VERTEX_AXIS = "v"


def max_vertex_shards() -> int:
    """Upper bound on useful vertex shards: the local device count."""
    return jax.local_device_count()


def vertex_mesh(shards: int) -> Mesh:
    """1-D mesh of the first ``shards`` local devices, axis ``"v"``.

    ``shards`` must be in [1, local_device_count()]; callers clamp (the
    scheduler degrades to fewer shards rather than failing when a config
    asks for more devices than the host has).
    """
    if not 1 <= shards <= jax.local_device_count():
        raise ValueError(
            f"vertex_mesh needs 1 <= shards <= {jax.local_device_count()} "
            f"local devices (got {shards})"
        )
    devices = np.asarray(jax.local_devices()[:shards])
    return Mesh(devices, (VERTEX_AXIS,))


def pad_rows_to_multiple(rows: int, shards: int) -> int:
    """Rows of padding needed so ``rows`` divides evenly across ``shards``."""
    return (-rows) % shards
