from repro.data.client_bank import ClientBank, EvalBank, eval_sample_plan
from repro.data.mnist_like import make_mnist_like
from repro.data.partition import dirichlet_partition
from repro.data.tokens import TokenStream, synthetic_token_batches
