"""Zamba2-7B: Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    hybrid_attn_every=6,
    source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=3, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    hybrid_attn_every=2,
    source="reduced zamba2 family",
)
